//! The native decode session: per-layer K/V caches over
//! `runtime::native::model::incr_forward` — one prefill pass per
//! admitted prompt, then O(model) single-position steps — with each
//! slot carrying an [`AdapterExec`] picked by the admission cost model
//! (`cache::build_exec`): factored rank-r application by default,
//! dense weights from the shared [`ReconCache`] when one adapter
//! dominates the session's slots (or has no factored form).
//!
//! Every slot is independent (own adapter, own K/V cache, own budget),
//! so a session can decode a *heterogeneous* mix of adapters
//! concurrently: per-step compute is row-sized either way, and this is
//! exactly the multi-tenant story the paper's one-vector-per-task
//! storage enables — factored slots keep per-adapter residency at the
//! rank-r factors, so thousands of distinct adapters fit in a session.

use super::{DecodeSession, ReconCache, SeqEvent, SeqRequest, SeqState, SessionOpts, SessionStats};
use crate::config::ModelCfg;
use crate::runtime::artifact::ArtifactMeta;
use crate::runtime::native::model::{self, AdapterExec, KvCache};
use crate::runtime::Backend;
use anyhow::{anyhow, ensure, Result};
use std::sync::Arc;

struct Slot {
    /// adapter identity — what the cost model counts to decide when a
    /// hot adapter is worth densifying
    adapter: String,
    theta_fp: u64,
    exec: Arc<AdapterExec>,
    kv: KvCache,
    prompt: Vec<i32>,
    state: SeqState,
    /// last emitted token, fed at the next step
    pending: Option<i32>,
    prefilled: bool,
}

pub struct NativeDecodeSession {
    cfg: ModelCfg,
    w0: Arc<Vec<f32>>,
    /// backbone layout built once per session; rebound to w0 each step
    layout: model::BaseLayout,
    cache: Arc<ReconCache>,
    dense_threshold: usize,
    slots: Vec<Option<Slot>>,
    active: usize,
    stats: SessionStats,
}

impl NativeDecodeSession {
    pub fn new(
        meta: &ArtifactMeta,
        w0: Arc<Vec<f32>>,
        cache: Arc<ReconCache>,
        opts: &SessionOpts,
    ) -> Result<NativeDecodeSession> {
        ensure!(
            meta.kind == "lm_logits",
            "decode sessions need an lm_logits artifact; {} has kind {:?}",
            meta.name,
            meta.kind
        );
        ensure!(
            w0.len() == meta.base_params,
            "w0 size mismatch: got {}, artifact wants {}",
            w0.len(),
            meta.base_params
        );
        let n = opts.resolve_slots(meta.cfg.batch);
        Ok(NativeDecodeSession {
            layout: model::BaseLayout::new(&meta.cfg),
            cfg: meta.cfg.clone(),
            w0,
            cache,
            dense_threshold: opts.resolve_dense_threshold(),
            slots: (0..n).map(|_| None).collect(),
            active: 0,
            stats: SessionStats::default(),
        })
    }
}

impl DecodeSession for NativeDecodeSession {
    fn admit(&mut self, req: SeqRequest) -> Result<usize> {
        ensure!(!req.prompt.is_empty(), "empty prompt");
        let si = self
            .slots
            .iter()
            .position(|s| s.is_none())
            .ok_or_else(|| anyhow!("no free decode slot"))?;
        let theta_fp = super::theta_fingerprint(&req.theta);
        let same_adapter_active = self
            .slots
            .iter()
            .flatten()
            .filter(|s| s.adapter == req.adapter && s.theta_fp == theta_fp)
            .count();
        let fetch = super::cache::build_exec(
            &self.cache,
            &req.adapter,
            &self.cfg,
            &self.w0,
            &req.theta,
            &req.statics,
            same_adapter_active,
            self.dense_threshold,
        )?;
        if fetch.exec.is_dense() {
            self.stats.dense_admits += 1;
            if fetch.hit {
                self.stats.recon_hits += 1;
            } else {
                self.stats.recon_misses += 1;
            }
        } else {
            self.stats.factored_admits += 1;
        }
        self.stats.recon_evictions += fetch.evicted;
        let state = SeqState::new(req.prompt.len(), req.max_new, self.cfg.seq);
        let mut prompt = req.prompt;
        prompt.truncate(self.cfg.seq);
        self.slots[si] = Some(Slot {
            adapter: req.adapter,
            theta_fp,
            exec: fetch.exec,
            kv: KvCache::new(&self.cfg),
            prompt,
            state,
            pending: None,
            prefilled: false,
        });
        self.active += 1;
        self.stats.admitted += 1;
        Ok(si)
    }

    fn step(&mut self, _exec: &mut dyn Backend) -> Result<Vec<SeqEvent>> {
        let base = self.layout.bind(self.w0.as_slice())?;
        let mut events = Vec::new();
        for si in 0..self.slots.len() {
            let Some(slot) = self.slots[si].as_mut() else { continue };
            let hidden = if !slot.prefilled {
                slot.prefilled = true;
                if slot.state.stillborn() {
                    // the legacy loop's no-op rows: prompt fills the
                    // window, or zero budget — retire without a forward
                    events.push(SeqEvent { slot: si, token: None, done: true });
                    self.slots[si] = None;
                    self.active -= 1;
                    continue;
                }
                model::incr_forward(&self.cfg, &base, &slot.exec, &mut slot.kv, &slot.prompt)?
            } else {
                let tok = slot.pending.ok_or_else(|| anyhow!("active slot without pending"))?;
                model::incr_forward(&self.cfg, &base, &slot.exec, &mut slot.kv, &[tok])?
            };
            let logits = model::lm_logits_row(&self.cfg, &base, &hidden);
            let (token, done) = slot.state.emit(&logits);
            slot.pending = token;
            if token.is_some() {
                self.stats.generated += 1;
            }
            events.push(SeqEvent { slot: si, token, done });
            if done {
                self.slots[si] = None;
                self.active -= 1;
            }
        }
        self.stats.steps += 1;
        Ok(events)
    }

    fn finish(&mut self) {
        for s in self.slots.iter_mut() {
            *s = None;
        }
        self.active = 0;
    }

    fn slots(&self) -> usize {
        self.slots.len()
    }

    fn active(&self) -> usize {
        self.active
    }

    fn stats(&self) -> SessionStats {
        self.stats
    }
}
