//! The native decode session: arena-paged K/V over
//! `runtime::native::model::incr_forward_slot` — one prefill pass per
//! admitted prompt, then O(model) single-position steps — with each
//! slot carrying an [`AdapterExec`] picked by the admission cost model
//! (`cache::build_exec`): factored rank-r application by default,
//! dense weights from the shared [`ReconCache`] when one adapter
//! dominates the session's slots (or has no factored form).
//!
//! K/V storage is one session-owned [`KvArena`]: slots hold short page
//! tables instead of full-window buffers, admission reserves the
//! worst case a sequence can need (`min(seq, prompt + max_new)`
//! positions, in page units) against a shared token budget, and
//! retirement recycles the pages. Idle slots hold zero pages, so
//! resident K/V bytes track tokens actually in flight.
//!
//! The step itself is *fused* by default: every active single-position
//! slot advances through one `[active, h]` GEMM per layer weight
//! (`incr_forward_batch`) and one `[active, vocab]` logits GEMM,
//! instead of per-slot GEMVs. Batching is scheduling-only — per-row
//! accumulation order is unchanged, so the fused step is bit-equal per
//! kernel tier to per-slot stepping (`UNI_LORA_FUSED_STEP=0`), and the
//! decode-parity suite pins both paths to the same streams.
//!
//! Every slot is independent (own adapter, own K/V pages, own budget),
//! so a session can decode a *heterogeneous* mix of adapters
//! concurrently — this is exactly the multi-tenant story the paper's
//! one-vector-per-task storage enables: factored slots keep
//! per-adapter residency at the rank-r factors, so thousands of
//! distinct adapters fit in a session, and the fused step still
//! batches them (shared-base GEMM + per-slot rank-r updates).

use super::{
    Admission, DecodeSession, ReconCache, SeqEvent, SeqRequest, SeqState, SessionOpts, SessionStats,
};
use crate::config::ModelCfg;
use crate::obs::profile;
use crate::runtime::artifact::ArtifactMeta;
use crate::runtime::native::model::{self, AdapterExec, KvArena, KvSlot};
use crate::runtime::Backend;
use anyhow::{anyhow, ensure, Result};
use std::sync::Arc;

struct Slot {
    /// [`SeqRequest::request_id`], echoed on every event this slot
    /// emits (observation-only)
    request_id: u64,
    /// adapter identity — what the cost model counts to decide when a
    /// hot adapter is worth densifying
    adapter: String,
    theta_fp: u64,
    exec: Arc<AdapterExec>,
    kv: KvSlot,
    prompt: Vec<i32>,
    state: SeqState,
    /// last emitted token, fed at the next step
    pending: Option<i32>,
    prefilled: bool,
}

pub struct NativeDecodeSession {
    cfg: ModelCfg,
    w0: Arc<Vec<f32>>,
    /// backbone layout built once per session; rebound to w0 each step
    layout: model::BaseLayout,
    cache: Arc<ReconCache>,
    dense_threshold: usize,
    arena: KvArena,
    fused: bool,
    slots: Vec<Option<Slot>>,
    active: usize,
    stats: SessionStats,
}

impl NativeDecodeSession {
    pub fn new(
        meta: &ArtifactMeta,
        w0: Arc<Vec<f32>>,
        cache: Arc<ReconCache>,
        opts: &SessionOpts,
    ) -> Result<NativeDecodeSession> {
        ensure!(
            meta.kind == "lm_logits",
            "decode sessions need an lm_logits artifact; {} has kind {:?}",
            meta.name,
            meta.kind
        );
        ensure!(
            w0.len() == meta.base_params,
            "w0 size mismatch: got {}, artifact wants {}",
            w0.len(),
            meta.base_params
        );
        let n = opts.resolve_slots(meta.cfg.batch);
        Ok(NativeDecodeSession {
            layout: model::BaseLayout::new(&meta.cfg),
            arena: KvArena::new(&meta.cfg, opts.resolve_kv_pages(n, meta.cfg.seq)),
            fused: opts.fused_step,
            cfg: meta.cfg.clone(),
            w0,
            cache,
            dense_threshold: opts.resolve_dense_threshold(),
            slots: (0..n).map(|_| None).collect(),
            active: 0,
            stats: SessionStats::default(),
        })
    }

    /// Free a slot and recycle its K/V pages.
    fn retire(&mut self, si: usize) {
        if let Some(mut slot) = self.slots[si].take() {
            self.arena.release(&mut slot.kv);
            self.active -= 1;
        }
    }
}

impl DecodeSession for NativeDecodeSession {
    fn cancel(&mut self, slot: usize) {
        if slot < self.slots.len() && self.slots[slot].is_some() {
            self.retire(slot);
            self.stats.cancelled += 1;
        }
    }

    fn admit(&mut self, req: SeqRequest) -> Result<Admission> {
        ensure!(!req.prompt.is_empty(), "empty prompt");
        req.sampling.validate()?;
        let si = self
            .slots
            .iter()
            .position(|s| s.is_none())
            .ok_or_else(|| anyhow!("no free decode slot"))?;
        let greedy = req.sampling.is_greedy();
        let state = SeqState::new(req.prompt.len(), req.max_new, self.cfg.seq, req.sampling);
        // Reserve K/V capacity before paying for reconstruction: the
        // worst case this sequence can occupy. Stillborn sequences
        // never run a forward, so they hold nothing.
        let kv_tokens = if state.stillborn() {
            0
        } else {
            (req.prompt.len() + req.max_new).min(self.cfg.seq)
        };
        let mut kv = self.arena.reserve(kv_tokens)?;
        let theta_fp = super::theta_fingerprint(&req.theta);
        let same_adapter_active = self
            .slots
            .iter()
            .flatten()
            .filter(|s| s.adapter == req.adapter && s.theta_fp == theta_fp)
            .count();
        let fetch = match super::cache::build_exec(
            &self.cache,
            &req.adapter,
            &self.cfg,
            &self.w0,
            &req.theta,
            &req.statics,
            same_adapter_active,
            self.dense_threshold,
        ) {
            Ok(fetch) => fetch,
            Err(e) => {
                self.arena.release(&mut kv);
                return Err(e);
            }
        };
        if fetch.exec.is_dense() {
            self.stats.dense_admits += 1;
            if fetch.hit {
                self.stats.recon_hits += 1;
            } else {
                self.stats.recon_misses += 1;
            }
        } else {
            self.stats.factored_admits += 1;
        }
        self.stats.recon_evictions += fetch.evicted;
        let truncated = req.prompt.len() > self.cfg.seq;
        if truncated {
            self.stats.truncated_admits += 1;
        }
        let mut prompt = req.prompt;
        prompt.truncate(self.cfg.seq);
        self.slots[si] = Some(Slot {
            request_id: req.request_id,
            adapter: req.adapter,
            theta_fp,
            exec: fetch.exec,
            kv,
            prompt,
            state,
            pending: None,
            prefilled: false,
        });
        self.active += 1;
        self.stats.admitted += 1;
        if greedy {
            self.stats.greedy_admits += 1;
        } else {
            self.stats.sampled_admits += 1;
        }
        Ok(Admission { slot: si, truncated })
    }

    fn step(&mut self, _exec: &mut dyn Backend) -> Result<Vec<SeqEvent>> {
        let base = self.layout.bind(self.w0.as_slice())?;
        let n = self.slots.len();
        let h = self.cfg.hidden;
        // Per-slot outcome of the forward passes: the final hidden row
        // each active slot produced this step.
        let mut hidden_rows: Vec<Option<Vec<f32>>> = (0..n).map(|_| None).collect();
        let mut stillborn: Vec<bool> = vec![false; n];

        // Pass 1 — first-step slots: retire stillborn sequences
        // without a forward; run multi-position prefills per slot.
        for si in 0..n {
            let Some(slot) = self.slots[si].as_mut() else { continue };
            if slot.prefilled {
                continue;
            }
            slot.prefilled = true;
            if slot.state.stillborn() {
                // the legacy loop's no-op rows: prompt fills the
                // window, or zero budget — retire without a forward
                stillborn[si] = true;
                continue;
            }
            let _prof = profile::stage(profile::STAGE_PREFILL);
            hidden_rows[si] = Some(model::incr_forward_slot(
                &self.cfg,
                &base,
                &slot.exec,
                &mut self.arena,
                &mut slot.kv,
                &slot.prompt,
            )?);
        }

        // Pass 2 — continuing slots advance one position each: fused
        // into a single batched forward, or per-slot when disabled.
        if self.fused {
            let mut batch_slots: Vec<usize> = Vec::new();
            let mut entries: Vec<model::BatchEntry> = Vec::new();
            for (si, s) in self.slots.iter_mut().enumerate() {
                let Some(slot) = s else { continue };
                if stillborn[si] || hidden_rows[si].is_some() {
                    continue;
                }
                let tok = slot.pending.ok_or_else(|| anyhow!("active slot without pending"))?;
                batch_slots.push(si);
                entries.push(model::BatchEntry { exec: slot.exec.as_ref(), kv: &mut slot.kv, tok });
            }
            if !entries.is_empty() {
                let batched =
                    model::incr_forward_batch(&self.cfg, &base, &mut self.arena, &mut entries)?;
                for (bi, &si) in batch_slots.iter().enumerate() {
                    hidden_rows[si] = Some(batched[bi * h..(bi + 1) * h].to_vec());
                }
            }
        } else {
            for si in 0..n {
                let Some(slot) = self.slots[si].as_mut() else { continue };
                if stillborn[si] || hidden_rows[si].is_some() {
                    continue;
                }
                let tok = slot.pending.ok_or_else(|| anyhow!("active slot without pending"))?;
                hidden_rows[si] = Some(model::incr_forward_slot(
                    &self.cfg,
                    &base,
                    &slot.exec,
                    &mut self.arena,
                    &mut slot.kv,
                    &[tok],
                )?);
            }
        }

        // Pass 3 — logits: one [active, vocab] GEMM when fused, else
        // the legacy per-row projection.
        let active_rows: Vec<usize> = (0..n).filter(|&si| hidden_rows[si].is_some()).collect();
        let mut logits_rows: Vec<Option<Vec<f32>>> = (0..n).map(|_| None).collect();
        if self.fused {
            if !active_rows.is_empty() {
                let _prof = profile::stage(profile::STAGE_LOGITS);
                let m = active_rows.len();
                let mut x = vec![0f32; m * h];
                for (ri, &si) in active_rows.iter().enumerate() {
                    x[ri * h..(ri + 1) * h].copy_from_slice(hidden_rows[si].as_ref().unwrap());
                }
                let all = model::lm_logits_batch(&self.cfg, &base, &x, m);
                let v = all.len() / m;
                for (ri, &si) in active_rows.iter().enumerate() {
                    logits_rows[si] = Some(all[ri * v..(ri + 1) * v].to_vec());
                }
            }
        } else {
            for &si in &active_rows {
                let _prof = profile::stage(profile::STAGE_LOGITS);
                logits_rows[si] =
                    Some(model::lm_logits_row(&self.cfg, &base, hidden_rows[si].as_ref().unwrap()));
            }
        }

        // Pass 4 — emission in slot index order, exactly the legacy
        // per-slot event order.
        let mut events = Vec::new();
        for si in 0..n {
            if stillborn[si] {
                // read the id before retire() consumes the slot
                let req = self.slots[si].as_ref().map_or(0, |s| s.request_id);
                events.push(SeqEvent { slot: si, req, token: None, done: true });
                self.retire(si);
                continue;
            }
            let Some(logits) = logits_rows[si].take() else { continue };
            let slot = self.slots[si].as_mut().ok_or_else(|| anyhow!("lost slot {si}"))?;
            let (token, done) = {
                let _prof = profile::stage(profile::STAGE_SAMPLING);
                slot.state.emit(&logits)
            };
            slot.pending = token;
            if token.is_some() {
                self.stats.generated += 1;
            }
            events.push(SeqEvent { slot: si, req: slot.request_id, token, done });
            if done {
                self.retire(si);
            }
        }
        self.stats.steps += 1;
        Ok(events)
    }

    fn finish(&mut self) {
        for si in 0..self.slots.len() {
            if let Some(mut slot) = self.slots[si].take() {
                self.arena.release(&mut slot.kv);
            }
        }
        self.active = 0;
    }

    fn slots(&self) -> usize {
        self.slots.len()
    }

    fn active(&self) -> usize {
        self.active
    }

    fn stats(&self) -> SessionStats {
        let mut st = self.stats;
        st.kv_bytes_in_flight = self.arena.bytes_in_flight() as u64;
        st.kv_page_churn = self.arena.page_churn();
        st
    }
}
