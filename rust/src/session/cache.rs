//! LRU adapter-reconstruction cache, shared by every worker's decode
//! sessions (the same `Arc` pattern as the router's statics cache).
//!
//! An adapter checkpoint is one tiny vector; its reconstruction — the
//! dense per-layer adapted q/v weights `W0 + scale*ΔW` — is
//! `2 * layers * h^2` floats. The legacy decode loop rebuilt that for
//! every generated token; a cache entry rebuilds it once per adapter
//! and every session on every worker shares the result.
//!
//! Entries are validated, not trusted: each remembers WHICH backbone
//! (`Weak` identity of the `Arc`'d w0) and WHICH theta (bit
//! fingerprint) it was reconstructed from, so a re-registered adapter
//! under the same name, or a session over a different backbone, misses
//! and rebuilds instead of serving stale weights.

use crate::config::ModelCfg;
use crate::projection::reconstruct::reconstruct_with_statics;
use crate::projection::statics::Static;
use crate::runtime::native::model::{adapted_weights, AdaptedWeights, BaseMap};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

struct Entry {
    eff: Arc<AdaptedWeights>,
    /// identity of the backbone the reconstruction was merged against
    w0: Weak<Vec<f32>>,
    theta_fp: u64,
    /// last-touch tick (LRU ordering)
    tick: u64,
}

/// Capacity-bounded, least-recently-used map from adapter name to its
/// reconstructed [`AdaptedWeights`]. All methods take `&self`; one
/// instance is shared across workers behind an `Arc`.
pub struct ReconCache {
    cap: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    inner: Mutex<HashMap<String, Entry>>,
}

impl ReconCache {
    /// `cap` = resident adapters (clamped to >= 1); see
    /// `config::parse_recon_cache` for the `UNI_LORA_RECON_CACHE` knob.
    pub fn new(cap: usize) -> ReconCache {
        ReconCache {
            cap: cap.max(1),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            inner: Mutex::new(HashMap::new()),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Get the reconstruction for `name`, rebuilding on miss (unknown
    /// name, different theta, different backbone). Returns
    /// `(weights, hit)`. Reconstruction runs OUTSIDE the lock so a
    /// first-touch adapter never stalls workers serving cached ones;
    /// racing workers may rebuild the same entry once each — the
    /// results are deterministic duplicates and the last insert wins.
    pub fn get_or_build(
        &self,
        name: &str,
        cfg: &ModelCfg,
        w0: &Arc<Vec<f32>>,
        theta: &[f32],
        statics: &[Static],
    ) -> Result<(Arc<AdaptedWeights>, bool)> {
        let fp = super::theta_fingerprint(theta);
        {
            let mut m = self.inner.lock().unwrap();
            if let Some(e) = m.get_mut(name) {
                let same_w0 = e.w0.upgrade().map(|a| Arc::ptr_eq(&a, w0)).unwrap_or(false);
                if same_w0 && e.theta_fp == fp {
                    e.tick = self.tick.fetch_add(1, Ordering::Relaxed);
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((e.eff.clone(), true));
                }
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let base = BaseMap::new(cfg, w0.as_slice())?;
        let deltas = reconstruct_with_statics(cfg, statics, theta)?;
        let eff = Arc::new(adapted_weights(cfg, &base, &deltas)?);
        let mut m = self.inner.lock().unwrap();
        let tick = self.tick.fetch_add(1, Ordering::Relaxed);
        m.insert(
            name.to_string(),
            Entry { eff: eff.clone(), w0: Arc::downgrade(w0), theta_fp: fp, tick },
        );
        while m.len() > self.cap {
            let oldest = m.iter().min_by_key(|(_, e)| e.tick).map(|(k, _)| k.clone());
            match oldest {
                Some(k) => {
                    m.remove(&k);
                }
                None => break,
            }
        }
        Ok((eff, false))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::statics::{d_effective, gen_statics, init_theta};
    use crate::rng;

    fn small_cfg() -> ModelCfg {
        let mut c = ModelCfg::test_base("uni");
        c.hidden = 16;
        c.layers = 2;
        c.rank = 2;
        c.d = 32;
        c
    }

    fn w0_for(cfg: &ModelCfg, seed: u64) -> Arc<Vec<f32>> {
        let mut w0 = Vec::new();
        for (i, s) in crate::runtime::spec::base_segments(cfg).iter().enumerate() {
            let sd = rng::child_seed(seed, rng::STREAM_BASE_INIT + 1000 * i as u64);
            w0.extend(crate::projection::statics::init_array(&s.init, s.numel(), sd).unwrap());
        }
        Arc::new(w0)
    }

    #[test]
    fn hit_on_same_identity_miss_on_changed_theta_or_backbone() {
        let cfg = small_cfg();
        let cache = ReconCache::new(8);
        let w0 = w0_for(&cfg, 1);
        let stats = gen_statics(&cfg, 1).unwrap();
        let theta: Vec<f32> = rng::normals(3, d_effective(&cfg)).iter().map(|v| 0.1 * v).collect();

        let (a, hit) = cache.get_or_build("x", &cfg, &w0, &theta, &stats).unwrap();
        assert!(!hit);
        let (b, hit) = cache.get_or_build("x", &cfg, &w0, &theta, &stats).unwrap();
        assert!(hit, "same name/theta/backbone must hit");
        assert!(Arc::ptr_eq(&a, &b), "hit must return the cached reconstruction");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));

        // re-registered adapter (same name, new theta) must rebuild
        let theta2: Vec<f32> = theta.iter().map(|v| v + 1.0).collect();
        let (_, hit) = cache.get_or_build("x", &cfg, &w0, &theta2, &stats).unwrap();
        assert!(!hit, "changed theta must miss");

        // a different backbone identity must rebuild too
        let w0b = Arc::new(w0.as_ref().clone());
        let (_, hit) = cache.get_or_build("x", &cfg, &w0b, &theta2, &stats).unwrap();
        assert!(!hit, "changed backbone must miss");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn evicts_least_recently_used_past_capacity() {
        let cfg = small_cfg();
        let cache = ReconCache::new(2);
        let w0 = w0_for(&cfg, 1);
        let stats = gen_statics(&cfg, 1).unwrap();
        let theta = init_theta(&cfg, 2).unwrap();
        cache.get_or_build("a", &cfg, &w0, &theta, &stats).unwrap();
        cache.get_or_build("b", &cfg, &w0, &theta, &stats).unwrap();
        // touch "a" so "b" is the LRU entry
        assert!(cache.get_or_build("a", &cfg, &w0, &theta, &stats).unwrap().1);
        cache.get_or_build("c", &cfg, &w0, &theta, &stats).unwrap();
        assert_eq!(cache.len(), 2);
        // "a" survived, "b" was evicted
        assert!(cache.get_or_build("a", &cfg, &w0, &theta, &stats).unwrap().1);
        assert!(!cache.get_or_build("b", &cfg, &w0, &theta, &stats).unwrap().1);
    }

    #[test]
    fn fingerprint_separates_values_and_lengths() {
        use crate::session::theta_fingerprint as fp;
        assert_ne!(fp(&[1.0, 2.0]), fp(&[1.0, 2.5]));
        assert_ne!(fp(&[0.0]), fp(&[0.0, 0.0]));
        assert_eq!(fp(&[1.5; 7]), fp(&[1.5; 7]));
    }
}
