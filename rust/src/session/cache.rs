//! Adapter execution-form selection (the session cost model) and the
//! LRU dense-reconstruction cache behind it.
//!
//! An adapter checkpoint is one tiny vector. Since the factored
//! refactor, the DEFAULT way a decode slot applies it is
//! [`AdapterExec::Factored`]: the rank-r A/B factors straight from
//! reconstruction, applied as `y += scale*B(A x)` — per-adapter
//! resident state is `4 * layers * h * r` floats, which is what lets
//! one session serve thousands of distinct adapters.
//!
//! The [`ReconCache`] is demoted to a *hot-adapter optimization*: when
//! one adapter dominates a session's slots (at least `dense_threshold`
//! of them), [`build_exec`] densifies it once — `W0 + scale*ΔW`,
//! `2 * layers * h^2` floats — and every same-adapter slot shares the
//! cached result, trading residency for the cheapest per-step GEMV.
//! FourierFT's `Dense` module deltas have no factored form, so the
//! cost model (never the call sites) routes them dense regardless of
//! the threshold.
//!
//! Entries are validated, not trusted: each remembers WHICH backbone
//! (`Weak` identity of the `Arc`'d w0) and WHICH theta (bit
//! fingerprint) it was reconstructed from, so a re-registered adapter
//! under the same name, or a session over a different backbone, misses
//! and rebuilds instead of serving stale weights.

use crate::config::ModelCfg;
use crate::projection::reconstruct::reconstruct_with_statics;
use crate::projection::statics::Static;
use crate::runtime::native::model::{
    adapted_weights, AdaptedWeights, AdapterExec, BaseMap, FactoredWeights,
};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

struct Entry {
    eff: Arc<AdaptedWeights>,
    /// identity of the backbone the reconstruction was merged against
    w0: Weak<Vec<f32>>,
    theta_fp: u64,
    /// last-touch tick (LRU ordering)
    tick: u64,
}

/// Capacity-bounded, least-recently-used map from adapter name to its
/// reconstructed [`AdaptedWeights`]. All methods take `&self`; one
/// instance is shared across workers behind an `Arc`.
pub struct ReconCache {
    cap: usize,
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    inner: Mutex<HashMap<String, Entry>>,
}

impl ReconCache {
    /// `cap` = resident adapters (clamped to >= 1); see
    /// `config::parse_recon_cache` for the `UNI_LORA_RECON_CACHE` knob.
    pub fn new(cap: usize) -> ReconCache {
        ReconCache {
            cap: cap.max(1),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            inner: Mutex::new(HashMap::new()),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Dense reconstructions evicted over the cache's lifetime.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Bytes currently held by resident dense reconstructions — the
    /// memory the factored path exists to avoid; the multi-tenancy
    /// acceptance test budgets against this.
    pub fn resident_bytes(&self) -> usize {
        self.inner.lock().unwrap().values().map(|e| e.eff.byte_size()).sum()
    }

    /// Get the reconstruction for `name`, rebuilding on miss (unknown
    /// name, different theta, different backbone). Returns
    /// `(weights, hit, evicted)` where `evicted` counts entries this
    /// call pushed out of the LRU. Reconstruction runs OUTSIDE the
    /// lock so a first-touch adapter never stalls workers serving
    /// cached ones; racing workers may rebuild the same entry once
    /// each — the results are deterministic duplicates and the last
    /// insert wins.
    pub fn get_or_build(
        &self,
        name: &str,
        cfg: &ModelCfg,
        w0: &Arc<Vec<f32>>,
        theta: &[f32],
        statics: &[Static],
    ) -> Result<(Arc<AdaptedWeights>, bool, u64)> {
        let fp = super::theta_fingerprint(theta);
        {
            let mut m = self.inner.lock().unwrap();
            if let Some(e) = m.get_mut(name) {
                let same_w0 = e.w0.upgrade().map(|a| Arc::ptr_eq(&a, w0)).unwrap_or(false);
                if same_w0 && e.theta_fp == fp {
                    e.tick = self.tick.fetch_add(1, Ordering::Relaxed);
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((e.eff.clone(), true, 0));
                }
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let base = BaseMap::new(cfg, w0.as_slice())?;
        let deltas = reconstruct_with_statics(cfg, statics, theta)?;
        let eff = Arc::new(adapted_weights(cfg, &base, &deltas)?);
        let mut m = self.inner.lock().unwrap();
        let tick = self.tick.fetch_add(1, Ordering::Relaxed);
        m.insert(
            name.to_string(),
            Entry { eff: eff.clone(), w0: Arc::downgrade(w0), theta_fp: fp, tick },
        );
        let mut evicted = 0u64;
        while m.len() > self.cap {
            let oldest = m.iter().min_by_key(|(_, e)| e.tick).map(|(k, _)| k.clone());
            match oldest {
                Some(k) => {
                    m.remove(&k);
                    evicted += 1;
                }
                None => break,
            }
        }
        if evicted > 0 {
            self.evictions.fetch_add(evicted, Ordering::Relaxed);
        }
        Ok((eff, false, evicted))
    }
}

/// What [`build_exec`] resolved for one admission.
pub struct ExecFetch {
    pub exec: Arc<AdapterExec>,
    /// dense-cache hit (always `false` for factored admissions)
    pub hit: bool,
    /// dense reconstructions evicted on behalf of this admission
    pub evicted: u64,
}

/// The cost model: pick the execution form for an admission, given how
/// many slots the same (adapter, theta) already occupies in the
/// session. `same_adapter_active + 1 >= dense_threshold` densifies
/// through the shared [`ReconCache`]; otherwise the admission runs
/// factored — unless reconstruction yields any `Dense` module delta
/// (FourierFT), which has no factored form and falls back to the dense
/// path here, at the model, not at the call sites.
#[allow(clippy::too_many_arguments)]
pub fn build_exec(
    cache: &ReconCache,
    name: &str,
    cfg: &ModelCfg,
    w0: &Arc<Vec<f32>>,
    theta: &[f32],
    statics: &[Static],
    same_adapter_active: usize,
    dense_threshold: usize,
) -> Result<ExecFetch> {
    if same_adapter_active.saturating_add(1) >= dense_threshold {
        let (eff, hit, evicted) = cache.get_or_build(name, cfg, w0, theta, statics)?;
        return Ok(ExecFetch { exec: Arc::new(AdapterExec::Dense(eff)), hit, evicted });
    }
    let deltas = reconstruct_with_statics(cfg, statics, theta)?;
    match FactoredWeights::from_deltas(cfg, &deltas) {
        Some(fw) => {
            Ok(ExecFetch { exec: Arc::new(AdapterExec::Factored(fw)), hit: false, evicted: 0 })
        }
        None => {
            // dense module deltas (FourierFT) cannot run factored
            let (eff, hit, evicted) = cache.get_or_build(name, cfg, w0, theta, statics)?;
            Ok(ExecFetch { exec: Arc::new(AdapterExec::Dense(eff)), hit, evicted })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::statics::{d_effective, gen_statics, init_theta};
    use crate::rng;

    fn small_cfg() -> ModelCfg {
        let mut c = ModelCfg::test_base("uni");
        c.hidden = 16;
        c.layers = 2;
        c.rank = 2;
        c.d = 32;
        c
    }

    fn w0_for(cfg: &ModelCfg, seed: u64) -> Arc<Vec<f32>> {
        let mut w0 = Vec::new();
        for (i, s) in crate::runtime::spec::base_segments(cfg).iter().enumerate() {
            let sd = rng::child_seed(seed, rng::STREAM_BASE_INIT + 1000 * i as u64);
            w0.extend(crate::projection::statics::init_array(&s.init, s.numel(), sd).unwrap());
        }
        Arc::new(w0)
    }

    #[test]
    fn hit_on_same_identity_miss_on_changed_theta_or_backbone() {
        let cfg = small_cfg();
        let cache = ReconCache::new(8);
        let w0 = w0_for(&cfg, 1);
        let stats = gen_statics(&cfg, 1).unwrap();
        let theta: Vec<f32> = rng::normals(3, d_effective(&cfg)).iter().map(|v| 0.1 * v).collect();

        let (a, hit, _) = cache.get_or_build("x", &cfg, &w0, &theta, &stats).unwrap();
        assert!(!hit);
        let (b, hit, _) = cache.get_or_build("x", &cfg, &w0, &theta, &stats).unwrap();
        assert!(hit, "same name/theta/backbone must hit");
        assert!(Arc::ptr_eq(&a, &b), "hit must return the cached reconstruction");
        assert_eq!((cache.hits(), cache.misses()), (1, 1));

        // re-registered adapter (same name, new theta) must rebuild
        let theta2: Vec<f32> = theta.iter().map(|v| v + 1.0).collect();
        let (_, hit, _) = cache.get_or_build("x", &cfg, &w0, &theta2, &stats).unwrap();
        assert!(!hit, "changed theta must miss");

        // a different backbone identity must rebuild too
        let w0b = Arc::new(w0.as_ref().clone());
        let (_, hit, _) = cache.get_or_build("x", &cfg, &w0b, &theta2, &stats).unwrap();
        assert!(!hit, "changed backbone must miss");
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn evicts_least_recently_used_past_capacity() {
        let cfg = small_cfg();
        let cache = ReconCache::new(2);
        let w0 = w0_for(&cfg, 1);
        let stats = gen_statics(&cfg, 1).unwrap();
        let theta = init_theta(&cfg, 2).unwrap();
        cache.get_or_build("a", &cfg, &w0, &theta, &stats).unwrap();
        cache.get_or_build("b", &cfg, &w0, &theta, &stats).unwrap();
        assert_eq!(cache.evictions(), 0);
        // two residents of 2*layers*h^2 floats each
        let dense_bytes = 2 * cfg.layers * cfg.hidden * cfg.hidden * std::mem::size_of::<f32>();
        assert_eq!(cache.resident_bytes(), 2 * dense_bytes);
        // touch "a" so "b" is the LRU entry
        assert!(cache.get_or_build("a", &cfg, &w0, &theta, &stats).unwrap().1);
        let (_, _, evicted) = cache.get_or_build("c", &cfg, &w0, &theta, &stats).unwrap();
        assert_eq!(evicted, 1, "inserting past capacity must evict");
        assert_eq!(cache.evictions(), 1);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.resident_bytes(), 2 * dense_bytes);
        // "a" survived, "b" was evicted
        assert!(cache.get_or_build("a", &cfg, &w0, &theta, &stats).unwrap().1);
        assert!(!cache.get_or_build("b", &cfg, &w0, &theta, &stats).unwrap().1);
    }

    #[test]
    fn fingerprint_separates_values_and_lengths() {
        use crate::session::theta_fingerprint as fp;
        assert_ne!(fp(&[1.0, 2.0]), fp(&[1.0, 2.5]));
        assert_ne!(fp(&[0.0]), fp(&[0.0, 0.0]));
        assert_eq!(fp(&[1.5; 7]), fp(&[1.5; 7]));
    }

    #[test]
    fn cost_model_picks_factored_below_threshold_dense_at_it() {
        let cfg = small_cfg();
        let cache = ReconCache::new(8);
        let w0 = w0_for(&cfg, 4);
        let stats = gen_statics(&cfg, 4).unwrap();
        let theta: Vec<f32> = rng::normals(5, d_effective(&cfg)).iter().map(|v| 0.1 * v).collect();

        // below the crossover: factored, and the dense cache is untouched
        let f = build_exec(&cache, "x", &cfg, &w0, &theta, &stats, 0, 4).unwrap();
        assert!(!f.exec.is_dense());
        assert!(!f.hit);
        assert_eq!(cache.len(), 0, "factored admissions must not densify");

        // at the crossover (3 active + this one = 4): densified
        let d = build_exec(&cache, "x", &cfg, &w0, &theta, &stats, 3, 4).unwrap();
        assert!(d.exec.is_dense());
        assert_eq!(cache.len(), 1);

        // threshold 1 = legacy always-dense, even for a cold adapter
        let d1 = build_exec(&cache, "y", &cfg, &w0, &theta, &stats, 0, 1).unwrap();
        assert!(d1.exec.is_dense());

        // threshold MAX never densifies a low-rank adapter
        let fmax = build_exec(&cache, "z", &cfg, &w0, &theta, &stats, 1000, usize::MAX).unwrap();
        assert!(!fmax.exec.is_dense());
    }

    #[test]
    fn fourierft_routes_dense_regardless_of_threshold() {
        let mut cfg = small_cfg();
        cfg.method = "fourierft".into();
        let cache = ReconCache::new(8);
        let w0 = w0_for(&cfg, 6);
        let stats = gen_statics(&cfg, 6).unwrap();
        let theta = init_theta(&cfg, 6).unwrap();
        // spectral deltas have no factored form: the cost model owns
        // the dense fallback even at an always-factored threshold
        let f = build_exec(&cache, "ft", &cfg, &w0, &theta, &stats, 0, usize::MAX).unwrap();
        assert!(f.exec.is_dense());
        assert_eq!(cache.len(), 1);
    }
}
