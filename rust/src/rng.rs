//! Counter-based SplitMix64 PRNG — bit-identical with
//! `python/compile/unirng.py` (golden-tested on both sides).
//!
//! Everything random in this system flows from these streams: the
//! Uni-LoRA projection indices, every method's frozen statics, base
//! weight init, theta init, and the synthetic data generators. That is
//! what makes the paper's storage claim real here: an adapter checkpoint
//! is literally `(seed, theta_d)` and the Rust side reconstructs the
//! rest from the same streams Python used at build/test time.

pub const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;
pub const MIX1: u64 = 0xBF58_476D_1CE4_E5B9;
pub const MIX2: u64 = 0x94D0_49BB_1331_11EB;
pub const CHILD: u64 = 0xA24B_AED4_963E_E407;

// Shared stream ids (must match python/compile/unirng.py).
pub const STREAM_IDX: u64 = 1;
pub const STREAM_THETA_INIT: u64 = 2;
pub const STREAM_VERA_PB: u64 = 3;
pub const STREAM_VERA_PA: u64 = 4;
pub const STREAM_FASTFOOD: u64 = 5;
pub const STREAM_VB_TOPIDX: u64 = 6;
pub const STREAM_XS_BASES: u64 = 7;
pub const STREAM_FOURIER_FREQ: u64 = 8;
pub const STREAM_BASE_INIT: u64 = 9;
/// Serving-side sampling draws (`generation::Sampler`). Rust-only: the
/// Python compiler never samples, so this id has no python/compile
/// counterpart — it is reserved here so no future shared stream can
/// collide with it.
pub const STREAM_SAMPLE: u64 = 10;
pub const STREAM_DATA: u64 = 100;

/// SplitMix64 finalizer.
#[inline]
pub fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(MIX1);
    z = (z ^ (z >> 27)).wrapping_mul(MIX2);
    z ^ (z >> 31)
}

/// Derive an independent sub-seed for a named stream.
pub fn child_seed(seed: u64, stream: u64) -> u64 {
    mix(seed ^ stream.wrapping_mul(CHILD))
}

/// Stateless stream access: value(seed, i) = mix(seed + (i+1)*GOLDEN).
#[inline]
pub fn value(seed: u64, i: u64) -> u64 {
    mix(seed.wrapping_add((i + 1).wrapping_mul(GOLDEN)))
}

/// A cheap iterator view over a stream.
#[derive(Debug, Clone, Copy)]
pub struct Stream {
    pub seed: u64,
    pub pos: u64,
}

impl Stream {
    pub fn new(seed: u64) -> Stream {
        Stream { seed, pos: 0 }
    }

    pub fn child(seed: u64, stream: u64) -> Stream {
        Stream::new(child_seed(seed, stream))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let v = value(self.seed, self.pos);
        self.pos += 1;
        v
    }

    /// Uniform double in [0, 1) with 53-bit mantissa.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform index in [0, d).
    #[inline]
    pub fn next_index(&mut self, d: usize) -> usize {
        (self.next_u64() % d as u64) as usize
    }

    pub fn next_range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        (self.next_f64() * (hi as f64 - lo as f64) + lo as f64) as f32
    }
}

pub fn u64_stream(seed: u64, n: usize) -> Vec<u64> {
    (0..n as u64).map(|i| value(seed, i)).collect()
}

pub fn uniform01(seed: u64, n: usize) -> Vec<f64> {
    (0..n as u64)
        .map(|i| (value(seed, i) >> 11) as f64 * (1.0 / (1u64 << 53) as f64))
        .collect()
}

pub fn indices(seed: u64, n: usize, d: usize) -> Vec<i32> {
    (0..n as u64).map(|i| (value(seed, i) % d as u64) as i32).collect()
}

pub fn uniform_range(seed: u64, n: usize, lo: f32, hi: f32) -> Vec<f32> {
    uniform01(seed, n)
        .into_iter()
        .map(|u| (u * (hi as f64 - lo as f64) + lo as f64) as f32)
        .collect()
}

/// n float32 standard normals via pairwise Box-Muller — identical
/// pairing with unirng.normals (first half cos, second half sin).
pub fn normals(seed: u64, n: usize) -> Vec<f32> {
    let m = (n + 1) / 2;
    let u = uniform01(seed, 2 * m);
    let mut out = Vec::with_capacity(2 * m);
    for k in 0..m {
        let r = (-2.0 * (1.0 - u[k]).ln()).sqrt();
        out.push((r * (2.0 * std::f64::consts::PI * u[m + k]).cos()) as f32);
    }
    for k in 0..m {
        let r = (-2.0 * (1.0 - u[k]).ln()).sqrt();
        out.push((r * (2.0 * std::f64::consts::PI * u[m + k]).sin()) as f32);
    }
    out.truncate(n);
    out
}

/// n float32 values in {-1, +1} from bit 0.
pub fn signs(seed: u64, n: usize) -> Vec<f32> {
    (0..n as u64)
        .map(|i| if value(seed, i) & 1 == 1 { 1.0 } else { -1.0 })
        .collect()
}

/// Fisher-Yates permutation of 0..n-1 — identical with unirng.permutation.
pub fn permutation(seed: u64, n: usize) -> Vec<i32> {
    let vals = u64_stream(seed, n);
    let mut p: Vec<i32> = (0..n as i32).collect();
    for i in (1..n).rev() {
        let j = (vals[n - 1 - i] % (i as u64 + 1)) as usize;
        p.swap(i, j);
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Must match unirng.GOLDEN_SEED42 — the cross-language contract.
    const GOLDEN_SEED42: [u64; 4] = [
        0xBDD7_3226_2FEB_6E95,
        0x28EF_E333_B266_F103,
        0x4752_6757_130F_9F52,
        0x581C_E1FF_0E4A_E394,
    ];

    #[test]
    fn golden_seed42() {
        assert_eq!(u64_stream(42, 4), GOLDEN_SEED42);
    }

    /// Values printed by python: unirng.permutation(7, 8), indices(3,8,10),
    /// child_seed(42, 1), normals(7, 6).
    #[test]
    fn python_parity_goldens() {
        assert_eq!(permutation(7, 8), vec![1, 4, 5, 2, 6, 0, 3, 7]);
        assert_eq!(indices(3, 8, 10), vec![3, 1, 9, 7, 6, 5, 2, 0]);
        assert_eq!(child_seed(42, 1), 16449314825907640220);
        let z = normals(7, 6);
        let want = [-0.86208445, -0.17586078, 0.00767775, -0.4948181, 0.05417212, 2.1495075];
        for (a, b) in z.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn stream_matches_vectorized() {
        let mut s = Stream::new(99);
        let v = u64_stream(99, 16);
        for (i, want) in v.iter().enumerate() {
            assert_eq!(s.next_u64(), *want, "pos {i}");
        }
    }

    #[test]
    fn indices_in_range_many_seeds() {
        for seed in 0..50u64 {
            let idx = indices(seed, 257, 17);
            assert!(idx.iter().all(|&i| (0..17).contains(&i)));
        }
    }

    #[test]
    fn permutation_is_permutation_many() {
        for seed in 0..50u64 {
            let n = 1 + (seed as usize * 7) % 200;
            let mut p = permutation(seed, n);
            p.sort();
            assert_eq!(p, (0..n as i32).collect::<Vec<_>>());
        }
    }

    #[test]
    fn normals_moments() {
        let z = normals(123, 200_000);
        let mean = z.iter().map(|&x| x as f64).sum::<f64>() / z.len() as f64;
        let var = z.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / z.len() as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var.sqrt() - 1.0).abs() < 0.01, "std {}", var.sqrt());
    }

    #[test]
    fn signs_balanced() {
        let s = signs(5, 100_000);
        let mean = s.iter().sum::<f32>() / s.len() as f32;
        assert!(mean.abs() < 0.02);
    }

    #[test]
    fn child_seeds_distinct() {
        let mut set = std::collections::HashSet::new();
        for k in 0..64 {
            set.insert(child_seed(42, k));
        }
        assert_eq!(set.len(), 64);
    }

    #[test]
    fn uniform_range_bounds() {
        let u = uniform_range(9, 10_000, -0.02, 0.02);
        assert!(u.iter().all(|&x| (-0.02..0.02).contains(&x)));
    }
}
