//! End-to-end step latency (L2+L3 perf accounting): per-family
//! train/eval step medians, the runtime's execute breakdown, and
//! model-level GFLOP/s — swept over kernel variant (scalar vs simd,
//! via `kernels::set_choice`) x kernel threads (1 vs N) so both the
//! SIMD tier's per-core win and the pool's scaling are visible in one
//! run. A per-shape kernel microbench (gemm_nn/tn/nt GFLOP/s at
//! threads = 1 for each tier) leads the run: that is the recorded perf
//! trajectory — with `UNI_LORA_BENCH_JSON=1` every entry is serialized
//! into `BENCH_kernels.json` at the repo root.
//! Runs on whatever backend `UNI_LORA_BACKEND` selects (default:
//! native — no artifacts needed). Run: cargo bench --bench train_step

use uni_lora::bench::{bench, black_box, fmt_time, write_json_report, BenchResult};
use uni_lora::config::{KernelChoice, ModelCfg, RuntimeOpts};
use uni_lora::coordinator::{init_base, ClsTrainer, Hyper, LmTrainer};
use uni_lora::data::batcher::{cls_batches, lm_batches};
use uni_lora::data::{glue, math_tasks};
use uni_lora::kernels::{self, dispatch, KernelOps};
use uni_lora::rng;
use uni_lora::runtime::{Backend, TensorIn};
use uni_lora::util::json::{self, Json};

/// Forward-pass FLOPs for the transformer backbone (2 FLOPs per MAC;
/// attention counts the causal half of the score/mix matrices).
fn forward_flops(cfg: &ModelCfg) -> f64 {
    let (b, t, h, f) = (cfg.batch as f64, cfg.seq as f64, cfg.hidden as f64, cfg.ffn as f64);
    let nh = cfg.heads as f64;
    let hd = h / nh;
    let bt = b * t;
    let proj = 4.0 * 2.0 * bt * h * h; // q/k/v/o projections
    let attn = 2.0 * 2.0 * b * nh * (t * (t + 1.0) / 2.0) * hd; // qk^T + att@v
    let ffn = 2.0 * 2.0 * bt * h * f;
    cfg.layers as f64 * (proj + attn + ffn)
}

/// Training-step FLOPs, approximated as 3x forward (activation +
/// weight gradients roughly double the forward work) plus the head.
fn train_flops(cfg: &ModelCfg) -> f64 {
    let head = if cfg.n_classes > 0 {
        2.0 * cfg.batch as f64 * cfg.hidden as f64 * cfg.n_classes as f64
    } else {
        2.0 * (cfg.batch * cfg.seq) as f64 * cfg.hidden as f64 * cfg.vocab as f64
    };
    3.0 * (forward_flops(cfg) + head)
}

fn gflops_line(flops: f64, median_secs: f64) -> f64 {
    let gflops = flops / median_secs / 1e9;
    println!("   ~{:.2} GFLOP/s (est. {:.0} MFLOP/step)", gflops, flops / 1e6);
    gflops
}

/// One JSON trajectory entry: the timed result's own serialization
/// (`BenchResult::to_json`: name/median/min/max/iters) plus the
/// shape / variant / GFLOP/s context of the measurement.
#[allow(clippy::too_many_arguments)]
fn entry(
    r: &BenchResult,
    bench_name: &str,
    shape: &str,
    n: usize,
    k: usize,
    m: usize,
    variant: &str,
    path: &str,
    threads: usize,
    gflops: f64,
) -> Json {
    let mut j = r.to_json();
    if let Json::Obj(map) = &mut j {
        map.insert("bench".into(), json::s(bench_name));
        map.insert("shape".into(), json::s(shape));
        map.insert("n".into(), json::n(n as f64));
        map.insert("k".into(), json::n(k as f64));
        map.insert("m".into(), json::n(m as f64));
        map.insert("variant".into(), json::s(variant));
        map.insert("path".into(), json::s(path));
        map.insert("threads".into(), json::n(threads as f64));
        map.insert("gflops".into(), json::n(gflops));
    }
    j
}

/// Per-shape kernel GFLOP/s, scalar vs simd, at threads = 1 — the
/// microkernel comparison the acceptance criterion reads (the simd
/// tier should clear 2x scalar on an AVX2 host).
fn kernel_sweep(entries: &mut Vec<Json>) {
    kernels::set_threads(1);
    println!("=== kernel microbench: per-shape GFLOP/s, scalar vs simd (threads = 1) ===");
    let f = dispatch::detect();
    println!("cpu features: avx2 = {}, fma = {}", f.avx2, f.fma);
    let shapes: [(&str, usize, usize, usize); 3] = [
        ("base-qkv", 1024, 64, 64),     // glue base: bt x h x h projection
        ("lm-ffn", 1024, 128, 256),     // lm cfg: bt x h x ffn
        ("e2e-lmhead", 512, 256, 2048), // e2e cfg: bt x h x vocab
    ];
    let tiers: [(&'static KernelOps, &str); 2] =
        [(&dispatch::SCALAR, "scalar"), (dispatch::simd_ops(), "simd")];
    for (label, n, k, m) in shapes {
        let x = rng::normals(1, n * k);
        let w = rng::normals(2, k * m);
        let a_tn = rng::normals(3, n * k);
        let b_tn = rng::normals(4, n * m);
        let a_nt = rng::normals(5, n * m);
        let b_nt = rng::normals(6, k * m);
        let flops = 2.0 * (n * k * m) as f64;
        for (ops, vname) in tiers {
            let mut out = vec![0f32; n * m];
            let r = bench(&format!("kernel/gemm_nn/{label}/{vname}"), 2, 9, || {
                kernels::gemm_nn_with(ops, &x, &w, &mut out, n, k, m, false);
                black_box(out[0]);
            });
            let g = gflops_line(flops, r.median_secs);
            entries.push(entry(&r, "gemm_nn", label, n, k, m, vname, ops.path, 1, g));

            let mut out = vec![0f32; k * m];
            let r = bench(&format!("kernel/gemm_tn/{label}/{vname}"), 2, 9, || {
                kernels::gemm_tn_with(ops, &a_tn, &b_tn, &mut out, n, k, m, false);
                black_box(out[0]);
            });
            let g = gflops_line(flops, r.median_secs);
            entries.push(entry(&r, "gemm_tn", label, n, k, m, vname, ops.path, 1, g));

            let mut out = vec![0f32; n * k];
            let r = bench(&format!("kernel/gemm_nt/{label}/{vname}"), 2, 9, || {
                kernels::gemm_nt_with(ops, &a_nt, &b_nt, &mut out, n, k, m, false);
                black_box(out[0]);
            });
            let g = gflops_line(flops, r.median_secs);
            entries.push(entry(&r, "gemm_nt", label, n, k, m, vname, ops.path, 1, g));
        }
    }
}

fn run_all(entries: &mut Vec<Json>) -> anyhow::Result<()> {
    let mut exec = uni_lora::runtime::default_backend()?;
    println!("backend: {}", exec.name());
    let hp = Hyper::default();
    let variant = dispatch::variant().name();
    let path = dispatch::path();
    let threads = kernels::threads();
    let record = |entries: &mut Vec<Json>, r: &BenchResult, name: &str, cfg: &ModelCfg, gflops| {
        entries.push(entry(
            r,
            name,
            &cfg.name,
            cfg.batch * cfg.seq,
            cfg.hidden,
            cfg.ffn,
            variant,
            path,
            threads,
            gflops,
        ));
    };

    for family in ["glue_base_uni_c2", "glue_large_uni_c2"] {
        let meta = exec.meta(&format!("{family}_cls_train"))?.clone();
        let w0 = init_base(&meta, 42);
        let mut tr = ClsTrainer::new(exec.as_ref(), family, 42, w0)?;
        let split = glue::generate("sst2", 42, meta.cfg.seq, meta.cfg.vocab);
        let batch = &cls_batches(&split.train, meta.cfg.batch, 42, 0)[0];
        exec.prepare(&format!("{family}_cls_train"))?;
        exec.reset_stats();
        let r = bench(&format!("{family}/train_step"), 3, 15, || {
            tr.train_step(exec.as_mut(), batch, &hp).unwrap();
        });
        let g = gflops_line(train_flops(&meta.cfg), r.median_secs);
        record(entries, &r, &format!("{family}/train_step"), &meta.cfg, g);
        let st = exec.stats();
        println!(
            "   breakdown: execute {} | transfer {} over {} executions",
            fmt_time(st.execute_secs / st.executions.max(1) as f64),
            fmt_time(st.transfer_secs / st.executions.max(1) as f64),
            st.executions
        );
        // §Perf optimization: pin frozen inputs (w0 + statics) so they
        // are not re-supplied on every step.
        tr.pin_frozen(exec.as_mut())?;
        bench(&format!("{family}/train_step_pinned"), 3, 15, || {
            tr.train_step(exec.as_mut(), batch, &hp).unwrap();
        });
        exec.unpin_all();
        bench(&format!("{family}/eval_batch"), 2, 9, || {
            tr.eval_logits(exec.as_mut(), &split.dev[..meta.cfg.batch]).unwrap();
        });
    }

    for family in ["lm_uni", "lm_lora_r64"] {
        let meta = exec.meta(&format!("{family}_lm_train"))?.clone();
        let w0 = init_base(&meta, 42);
        let mut tr = LmTrainer::new(exec.as_ref(), family, 42, w0)?;
        let (split, _) = math_tasks::generate(42, meta.cfg.seq, 64, 4);
        let batch = &lm_batches(&split.train, meta.cfg.batch, 42, 0)[0];
        exec.prepare(&format!("{family}_lm_train"))?;
        let r = bench(&format!("{family}/train_step"), 2, 9, || {
            tr.train_step(exec.as_mut(), batch, &hp).unwrap();
        });
        let g = gflops_line(train_flops(&meta.cfg), r.median_secs);
        record(entries, &r, &format!("{family}/train_step"), &meta.cfg, g);
        tr.pin_frozen(exec.as_mut())?;
        bench(&format!("{family}/train_step_pinned"), 2, 9, || {
            tr.train_step(exec.as_mut(), batch, &hp).unwrap();
        });
        exec.unpin_all();
        let prompts: Vec<Vec<i32>> =
            split.dev.iter().map(|e| e.tokens[..e.prompt_len].to_vec()).collect();
        bench(&format!("{family}/decode_4tok_b{}", meta.cfg.batch), 1, 5, || {
            tr.greedy_decode(exec.as_mut(), &prompts, 4).unwrap();
        });
    }

    // pretraining step (the heaviest graph)
    {
        let art = "pretrain_lm_pretrain_lm";
        let meta = exec.meta(art)?.clone();
        let w0 = init_base(&meta, 42);
        let mut corpus = uni_lora::data::corpus::CorpusBatches::new(
            1, meta.cfg.batch, meta.cfg.seq, meta.cfg.vocab,
        );
        let (toks, labs) = corpus.next_batch();
        exec.prepare(art)?;
        let m = vec![0f32; meta.base_params];
        let v = vec![0f32; meta.base_params];
        let r = bench("pretrain_lm/step", 1, 5, || {
            exec.run(
                art,
                &[
                    TensorIn::F32(w0.clone()),
                    TensorIn::F32(m.clone()),
                    TensorIn::F32(v.clone()),
                    TensorIn::ScalarI32(1),
                    TensorIn::ScalarF32(1e-3),
                    TensorIn::ScalarF32(0.0),
                    TensorIn::I32(toks.clone()),
                    TensorIn::I32(labs.clone()),
                ],
            )
            .unwrap();
        });
        let g = gflops_line(train_flops(&meta.cfg), r.median_secs);
        record(entries, &r, "pretrain_lm/step", &meta.cfg, g);
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut entries = Vec::new();
    kernel_sweep(&mut entries);

    let auto = RuntimeOpts::from_env().threads;
    let mut counts = vec![1usize];
    if auto > 1 {
        counts.push(auto);
    }
    for choice in [KernelChoice::Scalar, KernelChoice::Simd] {
        kernels::set_choice(choice);
        for &tc in &counts {
            kernels::set_threads(tc);
            println!(
                "\n=== kernels = {} | kernel threads = {tc} (of {auto} available) ===",
                dispatch::path()
            );
            run_all(&mut entries)?;
        }
    }
    kernels::set_choice(RuntimeOpts::from_env().kernels);
    kernels::set_threads(auto);

    if let Some(p) = write_json_report("train_step", entries)? {
        println!("\nperf trajectory written to {}", p.display());
    }
    Ok(())
}
