//! End-to-end step latency (L2+L3 perf accounting): per-family
//! train/eval step medians, the runtime's execute breakdown, and
//! model-level GFLOP/s — swept at kernel threads = 1 vs N so the
//! blocked/threaded GEMM layer's scaling is visible in one run.
//! Runs on whatever backend `UNI_LORA_BACKEND` selects (default:
//! native — no artifacts needed). Run: cargo bench --bench train_step

use uni_lora::bench::{bench, fmt_time};
use uni_lora::config::{ModelCfg, RuntimeOpts};
use uni_lora::coordinator::{init_base, ClsTrainer, Hyper, LmTrainer};
use uni_lora::data::batcher::{cls_batches, lm_batches};
use uni_lora::data::{glue, math_tasks};
use uni_lora::runtime::{Backend, TensorIn};

/// Forward-pass FLOPs for the transformer backbone (2 FLOPs per MAC;
/// attention counts the causal half of the score/mix matrices).
fn forward_flops(cfg: &ModelCfg) -> f64 {
    let (b, t, h, f) = (cfg.batch as f64, cfg.seq as f64, cfg.hidden as f64, cfg.ffn as f64);
    let nh = cfg.heads as f64;
    let hd = h / nh;
    let bt = b * t;
    let proj = 4.0 * 2.0 * bt * h * h; // q/k/v/o projections
    let attn = 2.0 * 2.0 * b * nh * (t * (t + 1.0) / 2.0) * hd; // qk^T + att@v
    let ffn = 2.0 * 2.0 * bt * h * f;
    cfg.layers as f64 * (proj + attn + ffn)
}

/// Training-step FLOPs, approximated as 3x forward (activation +
/// weight gradients roughly double the forward work) plus the head.
fn train_flops(cfg: &ModelCfg) -> f64 {
    let head = if cfg.n_classes > 0 {
        2.0 * cfg.batch as f64 * cfg.hidden as f64 * cfg.n_classes as f64
    } else {
        2.0 * (cfg.batch * cfg.seq) as f64 * cfg.hidden as f64 * cfg.vocab as f64
    };
    3.0 * (forward_flops(cfg) + head)
}

fn gflops_line(flops: f64, median_secs: f64) {
    println!("   ~{:.2} GFLOP/s (est. {:.0} MFLOP/step)", flops / median_secs / 1e9, flops / 1e6);
}

fn run_all() -> anyhow::Result<()> {
    let mut exec = uni_lora::runtime::default_backend()?;
    println!("backend: {}", exec.name());
    let hp = Hyper::default();

    for family in ["glue_base_uni_c2", "glue_large_uni_c2"] {
        let meta = exec.meta(&format!("{family}_cls_train"))?.clone();
        let w0 = init_base(&meta, 42);
        let mut tr = ClsTrainer::new(exec.as_ref(), family, 42, w0)?;
        let split = glue::generate("sst2", 42, meta.cfg.seq, meta.cfg.vocab);
        let batch = &cls_batches(&split.train, meta.cfg.batch, 42, 0)[0];
        exec.prepare(&format!("{family}_cls_train"))?;
        exec.reset_stats();
        let r = bench(&format!("{family}/train_step"), 3, 15, || {
            tr.train_step(exec.as_mut(), batch, &hp).unwrap();
        });
        gflops_line(train_flops(&meta.cfg), r.median_secs);
        let st = exec.stats();
        println!(
            "   breakdown: execute {} | transfer {} over {} executions",
            fmt_time(st.execute_secs / st.executions.max(1) as f64),
            fmt_time(st.transfer_secs / st.executions.max(1) as f64),
            st.executions
        );
        // §Perf optimization: pin frozen inputs (w0 + statics) so they
        // are not re-supplied on every step.
        tr.pin_frozen(exec.as_mut())?;
        bench(&format!("{family}/train_step_pinned"), 3, 15, || {
            tr.train_step(exec.as_mut(), batch, &hp).unwrap();
        });
        exec.unpin_all();
        bench(&format!("{family}/eval_batch"), 2, 9, || {
            tr.eval_logits(exec.as_mut(), &split.dev[..meta.cfg.batch]).unwrap();
        });
    }

    for family in ["lm_uni", "lm_lora_r64"] {
        let meta = exec.meta(&format!("{family}_lm_train"))?.clone();
        let w0 = init_base(&meta, 42);
        let mut tr = LmTrainer::new(exec.as_ref(), family, 42, w0)?;
        let (split, _) = math_tasks::generate(42, meta.cfg.seq, 64, 4);
        let batch = &lm_batches(&split.train, meta.cfg.batch, 42, 0)[0];
        exec.prepare(&format!("{family}_lm_train"))?;
        let r = bench(&format!("{family}/train_step"), 2, 9, || {
            tr.train_step(exec.as_mut(), batch, &hp).unwrap();
        });
        gflops_line(train_flops(&meta.cfg), r.median_secs);
        tr.pin_frozen(exec.as_mut())?;
        bench(&format!("{family}/train_step_pinned"), 2, 9, || {
            tr.train_step(exec.as_mut(), batch, &hp).unwrap();
        });
        exec.unpin_all();
        let prompts: Vec<Vec<i32>> =
            split.dev.iter().map(|e| e.tokens[..e.prompt_len].to_vec()).collect();
        bench(&format!("{family}/decode_4tok_b{}", meta.cfg.batch), 1, 5, || {
            tr.greedy_decode(exec.as_mut(), &prompts, 4).unwrap();
        });
    }

    // pretraining step (the heaviest graph)
    {
        let art = "pretrain_lm_pretrain_lm";
        let meta = exec.meta(art)?.clone();
        let w0 = init_base(&meta, 42);
        let mut corpus = uni_lora::data::corpus::CorpusBatches::new(
            1, meta.cfg.batch, meta.cfg.seq, meta.cfg.vocab,
        );
        let (toks, labs) = corpus.next_batch();
        exec.prepare(art)?;
        let m = vec![0f32; meta.base_params];
        let v = vec![0f32; meta.base_params];
        let r = bench("pretrain_lm/step", 1, 5, || {
            exec.run(
                art,
                &[
                    TensorIn::F32(w0.clone()),
                    TensorIn::F32(m.clone()),
                    TensorIn::F32(v.clone()),
                    TensorIn::ScalarI32(1),
                    TensorIn::ScalarF32(1e-3),
                    TensorIn::ScalarF32(0.0),
                    TensorIn::I32(toks.clone()),
                    TensorIn::I32(labs.clone()),
                ],
            )
            .unwrap();
        });
        gflops_line(train_flops(&meta.cfg), r.median_secs);
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let auto = RuntimeOpts::from_env().threads;
    let mut counts = vec![1usize];
    if auto > 1 {
        counts.push(auto);
    }
    for &tc in &counts {
        uni_lora::kernels::set_threads(tc);
        println!("\n=== kernel threads = {tc} (of {auto} available) ===");
        run_all()?;
    }
    Ok(())
}
