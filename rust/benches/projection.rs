//! Projection complexity bench (paper §3.4 + Table 6's timing claim):
//!   Uni-LoRA gather      O(D)
//!   Fastfood (FWHT)      O(D log d)
//!   Dense Gaussian       O(D d)
//! plus the transpose (gradient) path and the kernel-tier comparison
//! for the FWHT butterfly hot loop (scalar vs simd vtable). With
//! `UNI_LORA_BENCH_JSON=1` the tier comparison is serialized into
//! `BENCH_kernels.json` at the repo root (merged with train_step's
//! entries). Run: cargo bench --bench projection

use uni_lora::bench::{bench, black_box, write_json_report, BenchResult};
use uni_lora::kernels::dispatch;
use uni_lora::projection::op::{registry, ProjectionOp};
use uni_lora::projection::reconstruct::ModuleDelta;
use uni_lora::projection::statics::{gen_statics, init_theta};
use uni_lora::projection::{fastfood, gaussian, uni};
use uni_lora::rng;
use uni_lora::util::json::{self, Json};

/// Reconstruct + pullback timings for one registered op. Taking
/// `&dyn ProjectionOp` straight off `registry()` means this bench
/// stops compiling if a method ever leaves the trait.
fn bench_op(op: &'static dyn ProjectionOp) {
    let m = op.method();
    let cfg = uni_lora::config::ModelCfg::test_base(m);
    let stats = gen_statics(&cfg, 1).unwrap();
    // random nonzero theta: several methods zero-init (lora B, fourierft
    // coef, ...) and their apply has zero-skip fast paths that would
    // make an init-theta timing meaningless
    let theta = rng::normals(7, init_theta(&cfg, 1).unwrap().len());
    let deltas = op.apply(&cfg, &stats, &theta).unwrap();
    // a cotangent with the apply output's geometry (contents arbitrary)
    let cot: Vec<ModuleDelta> = deltas
        .iter()
        .enumerate()
        .map(|(i, d)| match d {
            ModuleDelta::LowRank { a, b } => ModuleDelta::LowRank {
                a: rng::normals(50 + i as u64, a.len()),
                b: rng::normals(90 + i as u64, b.len()),
            },
            ModuleDelta::Dense(dw) => ModuleDelta::Dense(rng::normals(130 + i as u64, dw.len())),
        })
        .collect();
    bench(&format!("{m}/apply"), 1, 5, || {
        black_box(op.apply(&cfg, &stats, &theta).unwrap());
    });
    bench(&format!("{m}/vjp"), 1, 5, || {
        black_box(op.vjp(&cfg, &stats, &theta, &cot).unwrap());
    });
}

/// One trajectory entry: the timed result's own serialization
/// (`BenchResult::to_json`) plus shape / tier / op-rate context.
fn fwht_entry(r: &BenchResult, d: usize, vname: &str, path: &str, gflops: f64) -> Json {
    let mut j = r.to_json();
    if let Json::Obj(map) = &mut j {
        map.insert("bench".into(), json::s("fwht"));
        map.insert("shape".into(), json::s(&format!("d={d}")));
        map.insert("n".into(), json::n(d as f64));
        map.insert("variant".into(), json::s(vname));
        map.insert("path".into(), json::s(path));
        map.insert("gflops".into(), json::n(gflops));
    }
    j
}

/// Scalar vs simd for the FWHT butterfly chain (the projection layer's
/// vtable-routed hot loop) — per-shape op/s, serialized into the perf
/// trajectory. The tiers are bit-identical here by contract; only the
/// wall clock may differ.
fn fwht_tier_sweep() -> Vec<Json> {
    println!("-- FWHT butterflies: kernel tiers (scalar vs simd vtable) --");
    let mut entries = Vec::new();
    let tiers: [(fn(&mut [f32]), &str, &str); 2] = [
        (dispatch::SCALAR.fwht, "scalar", dispatch::SCALAR.path),
        (dispatch::simd_ops().fwht, "simd", dispatch::simd_ops().path),
    ];
    for logd in [10usize, 12, 14] {
        let d = 1usize << logd;
        // ops per transform: logd butterfly stages of d add/subs + the
        // final d-scale pass
        let flops = (d * logd + d) as f64;
        let x = rng::normals(7, d);
        for (f, vname, path) in tiers {
            let mut v = x.clone();
            let r = bench(&format!("fwht/d={d}/{vname}"), 2, 9, || {
                v.copy_from_slice(&x);
                f(&mut v);
                black_box(v[0]);
            });
            let gflops = flops / r.median_secs / 1e9;
            println!("   ~{gflops:.2} Gop/s");
            entries.push(fwht_entry(&r, d, vname, path, gflops));
        }
    }
    entries
}

fn main() {
    let entries = fwht_tier_sweep();
    if let Some(p) = write_json_report("projection", entries).unwrap() {
        println!("perf trajectory written to {}\n", p.display());
    }

    println!("-- ProjectionOp registry: reconstruct (apply) + pullback (vjp) --");
    for op in registry() {
        bench_op(*op);
    }
    println!();
    let d = 4096usize;
    println!("-- projection forward: R^{d} -> R^D --");
    let theta = rng::normals(1, d);
    for big_d in [65_536usize, 262_144, 1_048_576] {
        // uni: O(D) gather
        let idx = rng::indices(2, big_d, d);
        let nrm = uni::counts_to_nrm(&idx, d);
        let mut out = vec![0f32; big_d];
        let r_uni = bench(&format!("uni/gather D={big_d}"), 2, 9, || {
            uni::project(&theta, &idx, &nrm, &mut out);
            black_box(out[0]);
        });

        // fastfood: O(D log d) FWHT chain
        let nb = big_d / d;
        let blocks: Vec<fastfood::FastfoodBlock> =
            (0..nb).map(|i| fastfood::FastfoodBlock::generate(i as u64, d)).collect();
        let r_ff = bench(&format!("fastfood/fwht D={big_d}"), 2, 9, || {
            black_box(fastfood::project(&blocks, &theta, big_d));
        });

        // dense gaussian: O(D d) — only at the smallest D (too slow above)
        if big_d == 65_536 {
            let r_g = bench(&format!("gaussian/dense D={big_d}"), 1, 3, || {
                black_box(gaussian::project(7, &theta, big_d));
            });
            println!(
                "   speedup vs fastfood: {:.1}x, vs gaussian: {:.0}x",
                r_ff.median_secs / r_uni.median_secs,
                r_g.median_secs / r_uni.median_secs
            );
        } else {
            println!(
                "   speedup vs fastfood: {:.1}x",
                r_ff.median_secs / r_uni.median_secs
            );
        }
    }

    println!("-- transpose (gradient) path P^T g --");
    let big_d = 262_144;
    let idx = rng::indices(2, big_d, d);
    let nrm = uni::counts_to_nrm(&idx, d);
    let g = rng::normals(3, big_d);
    bench(&format!("uni/scatter_t D={big_d}"), 2, 9, || {
        black_box(uni::project_t(&g, &idx, &nrm, d));
    });

    println!("-- index generation (adapter load path) --");
    let cfg = {
        let mut c = uni_lora::config::ModelCfg::test_base("uni");
        c.hidden = 256;
        c.layers = 8;
        c.d = 4096;
        c
    };
    bench(&format!("uni/gen_indices D={}", cfg.d_full()), 1, 5, || {
        black_box(uni::gen_indices(&cfg, 42, uni::Variant::Uni));
    });
}
