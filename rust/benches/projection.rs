//! Projection complexity bench (paper §3.4 + Table 6's timing claim):
//!   Uni-LoRA gather      O(D)
//!   Fastfood (FWHT)      O(D log d)
//!   Dense Gaussian       O(D d)
//! plus the transpose (gradient) path. Run: cargo bench --bench projection

use uni_lora::bench::{bench, black_box};
use uni_lora::projection::{fastfood, gaussian, uni};
use uni_lora::rng;

fn main() {
    let d = 4096usize;
    println!("-- projection forward: R^{d} -> R^D --");
    let theta = rng::normals(1, d);
    for big_d in [65_536usize, 262_144, 1_048_576] {
        // uni: O(D) gather
        let idx = rng::indices(2, big_d, d);
        let nrm = uni::counts_to_nrm(&idx, d);
        let mut out = vec![0f32; big_d];
        let r_uni = bench(&format!("uni/gather D={big_d}"), 2, 9, || {
            uni::project(&theta, &idx, &nrm, &mut out);
            black_box(out[0]);
        });

        // fastfood: O(D log d) FWHT chain
        let nb = big_d / d;
        let blocks: Vec<fastfood::FastfoodBlock> =
            (0..nb).map(|i| fastfood::FastfoodBlock::generate(i as u64, d)).collect();
        let r_ff = bench(&format!("fastfood/fwht D={big_d}"), 2, 9, || {
            black_box(fastfood::project(&blocks, &theta, big_d));
        });

        // dense gaussian: O(D d) — only at the smallest D (too slow above)
        if big_d == 65_536 {
            let r_g = bench(&format!("gaussian/dense D={big_d}"), 1, 3, || {
                black_box(gaussian::project(7, &theta, big_d));
            });
            println!(
                "   speedup vs fastfood: {:.1}x, vs gaussian: {:.0}x",
                r_ff.median_secs / r_uni.median_secs,
                r_g.median_secs / r_uni.median_secs
            );
        } else {
            println!(
                "   speedup vs fastfood: {:.1}x",
                r_ff.median_secs / r_uni.median_secs
            );
        }
    }

    println!("-- transpose (gradient) path P^T g --");
    let big_d = 262_144;
    let idx = rng::indices(2, big_d, d);
    let nrm = uni::counts_to_nrm(&idx, d);
    let g = rng::normals(3, big_d);
    bench(&format!("uni/scatter_t D={big_d}"), 2, 9, || {
        black_box(uni::project_t(&g, &idx, &nrm, d));
    });

    println!("-- index generation (adapter load path) --");
    let cfg = {
        let mut c = uni_lora::config::ModelCfg::test_base("uni");
        c.hidden = 256;
        c.layers = 8;
        c.d = 4096;
        c
    };
    bench(&format!("uni/gen_indices D={}", cfg.d_full()), 1, 5, || {
        black_box(uni::gen_indices(&cfg, 42, uni::Variant::Uni));
    });
}
