//! Serving bench: throughput/latency of the multi-adapter router under
//! (a) single-adapter, (b) mixed-adapter workloads — quantifies the
//! batch-coalescing win, the adapter-residency footprint, and the
//! execution worker-pool scaling (workers = 1 vs N over cloned
//! backends). Kernel threads are pinned to 1 so the comparison
//! isolates worker-level parallelism from intra-op parallelism.
//! Runs on the default backend (native unless UNI_LORA_BACKEND=pjrt).
//! Run: cargo bench --bench serving

use std::sync::Arc;
use uni_lora::adapters::{AdapterCheckpoint, Registry};
use uni_lora::config::RuntimeOpts;
use uni_lora::coordinator::init_base;
use uni_lora::data::vocab;
use uni_lora::projection::statics::init_theta;
use uni_lora::runtime::Backend;
use uni_lora::server::{serve, ServerConfig};

fn run_with_workers(workers: usize) -> anyhow::Result<()> {
    let mut exec = uni_lora::runtime::default_backend()?;
    let art = "lm_uni_lm_logits";
    let meta = exec.meta(art)?.clone();
    let w0 = init_base(&meta, 42);
    exec.prepare(art)?;

    // 64 resident adapters (untrained — latency is what matters here)
    let registry = Registry::new();
    for i in 0..64u64 {
        registry.insert(
            format!("a{i}"),
            AdapterCheckpoint {
                seed: i,
                method: "uni".into(),
                artifact: art.into(),
                theta: init_theta(&meta.cfg, i).unwrap(),
                head: vec![],
            },
        );
    }
    if workers == 1 {
        println!(
            "backend: {} | 64 adapters resident in {} KiB total ({} KiB each)",
            exec.name(),
            registry.resident_bytes() / 1024,
            registry.resident_bytes() / 1024 / 64
        );
    }

    let handle = serve(
        ServerConfig::new("127.0.0.1:0", art).with_workers(workers),
        exec,
        Arc::new(registry),
        meta.cfg.clone(),
        w0,
    )?;

    let prompt = vec![vocab::BOS, vocab::Q_MARKER, vocab::digit(3), vocab::PLUS,
                      vocab::digit(4), vocab::EQUALS, vocab::A_MARKER];
    let n = 32;

    for (label, n_adapters) in [("single-adapter", 1usize), ("mixed-16-adapters", 16)] {
        // concurrent submissions through the router's sync API
        let t0 = std::time::Instant::now();
        let mut joins = Vec::new();
        for c in 0..4usize {
            let router = handle.router.clone();
            let prompt = prompt.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..n / 4 {
                    let a = format!("a{}", (c * 7 + i) % n_adapters);
                    router.generate(&a, prompt.clone(), 4).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let st = handle.router.stats.lock().unwrap().clone();
        println!(
            "workers={} {label:<20} {n} reqs in {wall:.2}s = {:.1} req/s | \
             mean batch {:.2} | mean latency {:.0}ms",
            handle.workers,
            n as f64 / wall,
            st.mean_batch_size(),
            st.mean_latency_ms()
        );
        *handle.router.stats.lock().unwrap() = Default::default();
    }
    handle.shutdown();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // workers scale across cores; kernel threads stay at 1 (see header)
    uni_lora::kernels::set_threads(1);
    let auto = RuntimeOpts::from_env().threads;
    let mut sweep = vec![1usize];
    if auto > 1 {
        sweep.push(auto);
    }
    for &w in &sweep {
        run_with_workers(w)?;
    }
    Ok(())
}
