//! Serving bench: (a) session decode vs the legacy full-forward decode
//! — tokens/s and time-to-first-token, the PR-5 acceptance numbers —
//! (b) the fused batched decode step vs per-slot stepping at 16-slot
//! occupancy (the PR-7 acceptance number, plus the paged-K/V residency
//! peak), (c) the adapter-count sweep (1/16/256 distinct adapters,
//! factored vs dense execution pinned through `SessionOpts`) and
//! (d) sampled-vs-greedy decoding through the streaming serve path
//! (tokens/s and TTFT-to-first-frame — the PR-8 acceptance numbers)
//! and (e) router throughput under single- and mixed-adapter
//! workloads across worker-pool widths. Kernel threads are pinned to
//! 1 so the comparisons isolate the decode algorithm and worker-level
//! parallelism from intra-op parallelism.
//!
//! With `UNI_LORA_BENCH_JSON=1` the decode comparison, the fused-step
//! comparison, the adapter sweep, the sampling comparison and the
//! router latency percentiles (p50/p95/p99 TTFT and decode-step time,
//! read from the router's histograms) land in `BENCH_serving.json` at
//! the repo root, and one Prometheus scrape of the serving metrics is
//! archived as `BENCH_metrics.prom` next to it
//! (`scripts/bench_snapshot.sh` archives both per commit).
//!
//! Runs on the default backend (native unless UNI_LORA_BACKEND=pjrt).
//! Run: cargo bench --bench serving

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;
use uni_lora::adapters::{AdapterCheckpoint, Registry};
use uni_lora::bench;
use uni_lora::config::RuntimeOpts;
use uni_lora::coordinator::init_base;
use uni_lora::data::vocab;
use uni_lora::generation::SamplingParams;
use uni_lora::projection::statics::{gen_statics, init_theta};
use uni_lora::runtime::Backend;
use uni_lora::server::protocol::{Request, Response};
use uni_lora::server::{serve, ServerConfig};
use uni_lora::session::{DecodeSession, FallbackSession, SeqRequest, SessionOpts};
use uni_lora::util::json::{n, obj, s, Json};

const ART: &str = "lm_uni_lm_logits";

fn bench_prompt() -> Vec<i32> {
    vec![
        vocab::BOS,
        vocab::Q_MARKER,
        vocab::digit(3),
        vocab::PLUS,
        vocab::digit(4),
        vocab::EQUALS,
        vocab::A_MARKER,
    ]
}

/// Drive `n_seqs` same-adapter sequences through a session, measuring
/// wall time, generated tokens, mean time-to-first-token and the peak
/// of the paged-K/V residency gauge across steps.
fn drive_session(
    sess: &mut dyn DecodeSession,
    exec: &mut dyn Backend,
    theta: &Arc<Vec<f32>>,
    statics: &Arc<Vec<uni_lora::projection::statics::Static>>,
    n_seqs: usize,
    max_new: usize,
) -> (f64, u64, f64, u64) {
    let prompt = bench_prompt();
    let t0 = Instant::now();
    let mut admitted = 0usize;
    let mut first_tok_at: Vec<Option<f64>> = vec![None; n_seqs];
    let mut owner: Vec<Option<usize>> = vec![None; sess.slots()];
    let mut generated = 0u64;
    let mut kv_peak = 0u64;
    while admitted < n_seqs || sess.active() > 0 {
        while sess.free_slots() > 0 && admitted < n_seqs {
            let slot = sess
                .admit(SeqRequest {
                    request_id: 0,
                    adapter: "bench".into(),
                    theta: theta.clone(),
                    statics: statics.clone(),
                    prompt: prompt.clone(),
                    max_new,
                    sampling: SamplingParams::default(),
                })
                .expect("admit")
                .slot;
            owner[slot] = Some(admitted);
            admitted += 1;
        }
        if sess.active() == 0 {
            break;
        }
        for ev in sess.step(exec).expect("step") {
            let si = owner[ev.slot].expect("owned slot");
            if ev.token.is_some() {
                generated += 1;
                if first_tok_at[si].is_none() {
                    first_tok_at[si] = Some(t0.elapsed().as_secs_f64());
                }
            }
            if ev.done {
                owner[ev.slot] = None;
            }
        }
        kv_peak = kv_peak.max(sess.stats().kv_bytes_in_flight);
    }
    let wall = t0.elapsed().as_secs_f64();
    let ttfts: Vec<f64> = first_tok_at.into_iter().flatten().collect();
    let mean_ttft =
        if ttfts.is_empty() { 0.0 } else { ttfts.iter().sum::<f64>() / ttfts.len() as f64 };
    (wall, generated, mean_ttft, kv_peak)
}

/// Acceptance comparison: incremental session decode vs the legacy
/// full-forward loop, same adapter, same prompts, `max_new = 16`.
fn decode_comparison() -> anyhow::Result<Vec<Json>> {
    let mut exec = uni_lora::runtime::default_backend()?;
    let meta = exec.meta(ART)?.clone();
    let w0 = Arc::new(init_base(&meta, 42));
    let theta = Arc::new(init_theta(&meta.cfg, 7)?);
    let statics = Arc::new(gen_statics(&meta.cfg, 7)?);
    let (n_seqs, max_new) = (16usize, 16usize);

    let mut entries = Vec::new();
    let mut recorded = Vec::new();
    for (label, full_forward) in [("full-forward", true), ("session", false)] {
        let mut sess: Box<dyn DecodeSession> = if full_forward {
            Box::new(FallbackSession::new(meta.clone(), w0.clone(), &SessionOpts::from_env())?)
        } else {
            exec.begin_decode(ART, w0.clone(), &SessionOpts::from_env())?
        };
        // warmup (reconstruction cache, allocators)
        drive_session(sess.as_mut(), exec.as_mut(), &theta, &statics, 2, 4);
        let (wall, generated, ttft, kv_peak) =
            drive_session(sess.as_mut(), exec.as_mut(), &theta, &statics, n_seqs, max_new);
        sess.finish();
        let tps = generated as f64 / wall.max(1e-9);
        println!(
            "decode {label:<13} {n_seqs} seqs x max_new={max_new}: {generated} tokens \
             in {wall:.2}s = {tps:.1} tok/s | mean ttft {:.1}ms",
            1000.0 * ttft
        );
        recorded.push(tps);
        entries.push(obj(vec![
            ("name", s(&format!("decode/{label}/seqs{n_seqs}/new{max_new}"))),
            ("tokens_per_sec", n(tps)),
            ("mean_ttft_ms", n(1000.0 * ttft)),
            ("kv_bytes_peak", n(kv_peak as f64)),
            ("generated", n(generated as f64)),
            ("wall_secs", n(wall)),
        ]));
    }
    if recorded.len() == 2 {
        println!(
            "decode speedup: session is {:.1}x the full-forward tokens/s \
             (acceptance floor: 3x)",
            recorded[1] / recorded[0].max(1e-9)
        );
    }
    Ok(entries)
}

/// Fused-step comparison: the batched decode step (all active rows
/// through one GEMM per layer weight) vs per-slot stepping, on the
/// same 16-sequence same-adapter workload. The acceptance bar is the
/// fused row strictly above the per-slot baseline at this occupancy;
/// the K/V residency peak is identical by construction (pages track
/// tokens, not the step schedule).
fn fused_comparison() -> anyhow::Result<Vec<Json>> {
    let mut exec = uni_lora::runtime::default_backend()?;
    let meta = exec.meta(ART)?.clone();
    let w0 = Arc::new(init_base(&meta, 42));
    let theta = Arc::new(init_theta(&meta.cfg, 7)?);
    let statics = Arc::new(gen_statics(&meta.cfg, 7)?);
    let (n_seqs, max_new) = (16usize, 16usize);

    let mut entries = Vec::new();
    let mut recorded = Vec::new();
    for (label, fused) in [("per-slot", false), ("fused", true)] {
        let opts = SessionOpts::with_slots(n_seqs).with_fused_step(fused);
        let mut sess = exec.begin_decode(ART, w0.clone(), &opts)?;
        // warmup (reconstruction cache, arena pages, allocators)
        drive_session(sess.as_mut(), exec.as_mut(), &theta, &statics, 2, 4);
        let (wall, generated, ttft, kv_peak) =
            drive_session(sess.as_mut(), exec.as_mut(), &theta, &statics, n_seqs, max_new);
        sess.finish();
        let tps = generated as f64 / wall.max(1e-9);
        println!(
            "step   {label:<13} {n_seqs} seqs x max_new={max_new}: {generated} tokens \
             in {wall:.2}s = {tps:.1} tok/s | kv peak {} KiB | mean ttft {:.1}ms",
            kv_peak / 1024, 1000.0 * ttft
        );
        recorded.push(tps);
        entries.push(obj(vec![
            ("name", s(&format!("step/{label}/seqs{n_seqs}/new{max_new}"))),
            ("tokens_per_sec", n(tps)),
            ("mean_ttft_ms", n(1000.0 * ttft)),
            ("kv_bytes_peak", n(kv_peak as f64)),
            ("generated", n(generated as f64)),
            ("wall_secs", n(wall)),
        ]));
    }
    if recorded.len() == 2 {
        println!(
            "fused-step speedup: {:.2}x per-slot tokens/s at {n_seqs}-slot occupancy \
             (acceptance floor: >1x)",
            recorded[1] / recorded[0].max(1e-9)
        );
    }
    Ok(entries)
}

/// Tentpole sweep: tokens/s and residency as the number of distinct
/// resident adapters grows (1 / 16 / 256), with the execution mode
/// pinned factored (threshold = usize::MAX) vs dense (threshold = 1)
/// through `SessionOpts`. 256 round-robin requests over a 16-slot
/// session either way, so the workload is identical and the entries
/// isolate the execution-mode cost: dense pays reconstruction +
/// ReconCache residency per distinct adapter, factored pays a rank-r
/// application per token.
fn adapter_sweep() -> anyhow::Result<Vec<Json>> {
    let mut exec = uni_lora::runtime::default_backend()?;
    let meta = exec.meta(ART)?.clone();
    let cfg = meta.cfg.clone();
    let w0 = Arc::new(init_base(&meta, 42));
    let statics = Arc::new(gen_statics(&cfg, 7)?);
    let prompt = bench_prompt();
    let (n_reqs, max_new) = (256usize, 4usize);

    let mut entries = Vec::new();
    for n_adapters in [1usize, 16, 256] {
        let thetas: Vec<Arc<Vec<f32>>> =
            (0..n_adapters).map(|i| Arc::new(init_theta(&cfg, i as u64).unwrap())).collect();
        for (mode, threshold) in [("factored", usize::MAX), ("dense", 1usize)] {
            let opts = SessionOpts::with_slots(16).with_dense_threshold(threshold);
            let mut sess = exec.begin_decode(ART, w0.clone(), &opts)?;
            let t0 = Instant::now();
            let mut admitted = 0usize;
            let mut generated = 0u64;
            let mut kv_peak = 0u64;
            while admitted < n_reqs || sess.active() > 0 {
                while sess.free_slots() > 0 && admitted < n_reqs {
                    let a = admitted % n_adapters;
                    sess.admit(SeqRequest {
                        request_id: 0,
                        adapter: format!("a{a}"),
                        theta: thetas[a].clone(),
                        statics: statics.clone(),
                        prompt: prompt.clone(),
                        max_new,
                        sampling: SamplingParams::default(),
                    })
                    .expect("admit");
                    admitted += 1;
                }
                if sess.active() == 0 {
                    break;
                }
                for ev in sess.step(exec.as_mut()).expect("step") {
                    if ev.token.is_some() {
                        generated += 1;
                    }
                }
                kv_peak = kv_peak.max(sess.stats().kv_bytes_in_flight);
            }
            let wall = t0.elapsed().as_secs_f64();
            let st = sess.stats();
            sess.finish();
            let tps = generated as f64 / wall.max(1e-9);
            println!(
                "sweep {mode:<9} n_adapters={n_adapters:<4} {n_reqs} reqs x \
                 max_new={max_new}: {tps:.1} tok/s | admits f/d \
                 {}/{} | recon evictions {} | kv peak {} KiB",
                st.factored_admits, st.dense_admits, st.recon_evictions, kv_peak / 1024
            );
            entries.push(obj(vec![
                ("name", s(&format!("adapters/{mode}/n{n_adapters}"))),
                ("tokens_per_sec", n(tps)),
                ("wall_secs", n(wall)),
                ("factored_admits", n(st.factored_admits as f64)),
                ("dense_admits", n(st.dense_admits as f64)),
                ("recon_evictions", n(st.recon_evictions as f64)),
                ("kv_bytes_peak", n(kv_peak as f64)),
            ]));
        }
    }
    Ok(entries)
}

/// Send one streamed `generate` over a raw socket and read frames
/// until the terminal one. Returns the token count and the wall time
/// from the request write to the FIRST frame — real time-to-first-byte
/// through the whole serve path, not a session-internal estimate.
fn stream_once(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    adapter: &str,
    prompt: &[i32],
    max_new: usize,
    sampling: &SamplingParams,
) -> anyhow::Result<(usize, f64)> {
    let req = Request::Generate {
        adapter: adapter.into(),
        prompt: prompt.to_vec(),
        max_new,
        sampling: sampling.clone(),
        stream: true,
        timeout_ms: 0,
    };
    let t0 = Instant::now();
    writeln!(writer, "{}", req.to_json())?;
    let mut first: Option<f64> = None;
    let mut count = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        match Response::parse(&line)? {
            Response::Frame { token, done, .. } => {
                if token.is_some() {
                    count += 1;
                    first.get_or_insert_with(|| t0.elapsed().as_secs_f64());
                }
                if done {
                    let t = first.unwrap_or_else(|| t0.elapsed().as_secs_f64());
                    return Ok((count, t));
                }
            }
            Response::Error(e) => anyhow::bail!("server error: {e}"),
            other => anyhow::bail!("unexpected response {other:?}"),
        }
    }
}

/// Satellite comparison: sampled vs greedy decoding through the
/// streaming serve path — tokens/s plus TTFT-to-first-frame, i.e. the
/// latency a streaming client actually observes. Seeded sampling
/// should cost a sort + one RNG draw per token over the greedy
/// argmax; the entries record how much of that shows up end to end.
fn sampling_comparison() -> anyhow::Result<Vec<Json>> {
    let mut exec = uni_lora::runtime::default_backend()?;
    let meta = exec.meta(ART)?.clone();
    let w0 = init_base(&meta, 42);
    exec.prepare(ART)?;
    let registry = Registry::new();
    registry.insert(
        "a0".into(),
        AdapterCheckpoint {
            seed: 9,
            method: "uni".into(),
            artifact: ART.into(),
            theta: init_theta(&meta.cfg, 9)?,
            head: vec![],
        },
    );
    let handle = serve(
        ServerConfig::new("127.0.0.1:0", ART).with_workers(1),
        exec,
        Arc::new(registry),
        meta.cfg.clone(),
        w0,
    )?;
    let stream = TcpStream::connect(handle.addr)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let prompt = bench_prompt();
    let (n_reqs, max_new) = (16usize, 16usize);
    let greedy = SamplingParams::default();
    let sampled = SamplingParams { temperature: 0.8, top_k: 12, seed: 9, ..Default::default() };

    let mut entries = Vec::new();
    for (label, params) in [("greedy", &greedy), ("sampled", &sampled)] {
        // warmup (reconstruction cache, arena pages)
        stream_once(&mut reader, &mut writer, "a0", &prompt, 4, params)?;
        let t0 = Instant::now();
        let mut generated = 0usize;
        let mut ttfts = Vec::new();
        for _ in 0..n_reqs {
            let (toks, ttft) =
                stream_once(&mut reader, &mut writer, "a0", &prompt, max_new, params)?;
            generated += toks;
            ttfts.push(ttft);
        }
        let wall = t0.elapsed().as_secs_f64();
        let tps = generated as f64 / wall.max(1e-9);
        let ttft_ms = 1000.0 * ttfts.iter().sum::<f64>() / ttfts.len().max(1) as f64;
        println!(
            "sampling {label:<8} {n_reqs} reqs x max_new={max_new}: {generated} tokens \
             in {wall:.2}s = {tps:.1} tok/s | ttft-to-first-frame {ttft_ms:.1}ms"
        );
        entries.push(obj(vec![
            ("name", s(&format!("sampling/{label}/seqs{n_reqs}/new{max_new}"))),
            ("tokens_per_sec", n(tps)),
            ("ttft_first_frame_ms", n(ttft_ms)),
            ("generated", n(generated as f64)),
            ("wall_secs", n(wall)),
        ]));
    }
    handle.shutdown();
    Ok(entries)
}

fn run_with_workers(workers: usize) -> anyhow::Result<Vec<Json>> {
    let mut exec = uni_lora::runtime::default_backend()?;
    let meta = exec.meta(ART)?.clone();
    let w0 = init_base(&meta, 42);
    exec.prepare(ART)?;

    // 64 resident adapters (untrained — latency is what matters here)
    let registry = Registry::new();
    for i in 0..64u64 {
        registry.insert(
            format!("a{i}"),
            AdapterCheckpoint {
                seed: i,
                method: "uni".into(),
                artifact: ART.into(),
                theta: init_theta(&meta.cfg, i).unwrap(),
                head: vec![],
            },
        );
    }
    if workers == 1 {
        println!(
            "backend: {} | 64 adapters resident in {} KiB total ({} KiB each)",
            exec.name(),
            registry.resident_bytes() / 1024,
            registry.resident_bytes() / 1024 / 64
        );
    }

    let handle = serve(
        ServerConfig::new("127.0.0.1:0", ART).with_workers(workers),
        exec,
        Arc::new(registry),
        meta.cfg.clone(),
        w0,
    )?;

    let prompt = bench_prompt();
    let n_reqs = 32;

    let mut entries = Vec::new();
    for (label, n_adapters) in [("single-adapter", 1usize), ("mixed-16-adapters", 16)] {
        // concurrent submissions through the router's sync API
        let t0 = std::time::Instant::now();
        let mut joins = Vec::new();
        for c in 0..4usize {
            let router = handle.router.clone();
            let prompt = prompt.clone();
            joins.push(std::thread::spawn(move || {
                for i in 0..n_reqs / 4 {
                    let a = format!("a{}", (c * 7 + i) % n_adapters);
                    router.generate(&a, prompt.clone(), 4).unwrap();
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let st = handle.router.stats.lock().unwrap().clone();
        println!(
            "workers={} {label:<20} {n_reqs} reqs in {wall:.2}s = {:.1} req/s | \
             {:.0} tok/s | ttft {:.0}ms | occ {:.2} slots | recon hit {:.0}%",
            handle.workers,
            n_reqs as f64 / wall,
            st.tokens_per_sec(),
            st.mean_ttft_ms(),
            st.mean_occupied_slots(),
            100.0 * st.recon_hit_rate(),
        );
        // percentile columns from the router's latency histograms —
        // the same distributions the `metrics` op scrapes
        let ttft = &st.hists.ttft;
        let step = &st.hists.step;
        let ms = 1000.0;
        println!(
            "workers={} {label:<20} ttft p50/p95/p99 {:.1}/{:.1}/{:.1}ms | \
             step p50/p95/p99 {:.2}/{:.2}/{:.2}ms",
            handle.workers,
            ms * ttft.quantile(0.50),
            ms * ttft.quantile(0.95),
            ms * ttft.quantile(0.99),
            ms * step.quantile(0.50),
            ms * step.quantile(0.95),
            ms * step.quantile(0.99),
        );
        entries.push(obj(vec![
            ("name", s(&format!("latency/workers{workers}/{label}"))),
            ("tokens_per_sec", n(st.tokens_per_sec())),
            ("decode_wall_secs", n(st.decode_wall_secs)),
            ("ttft_p50_ms", n(ms * ttft.quantile(0.50))),
            ("ttft_p95_ms", n(ms * ttft.quantile(0.95))),
            ("ttft_p99_ms", n(ms * ttft.quantile(0.99))),
            ("step_p50_ms", n(ms * step.quantile(0.50))),
            ("step_p95_ms", n(ms * step.quantile(0.95))),
            ("step_p99_ms", n(ms * step.quantile(0.99))),
        ]));
        *handle.router.stats.lock().unwrap() = Default::default();
    }
    // archive one Prometheus scrape next to the JSON trajectory so a
    // bench snapshot carries the full metric surface, not just the
    // columns extracted above
    if bench::json_report_enabled() {
        let mut client = uni_lora::server::server::Client::connect(handle.addr)?;
        let text = client.metrics_text()?;
        let path = bench::named_json_path("metrics").with_extension("prom");
        std::fs::write(&path, text)?;
        println!("recorded metrics scrape -> {}", path.display());
    }
    handle.shutdown();
    Ok(entries)
}

fn main() -> anyhow::Result<()> {
    // workers scale across cores; kernel threads stay at 1 (see header)
    uni_lora::kernels::set_threads(1);

    let entries = decode_comparison()?;
    if let Some(path) = bench::write_named_json_report("serving", "decode", entries)? {
        println!("recorded decode trajectory -> {}", path.display());
    }

    let fused_entries = fused_comparison()?;
    if let Some(path) = bench::write_named_json_report("serving", "fused_step", fused_entries)? {
        println!("recorded fused-step comparison -> {}", path.display());
    }

    let sweep_entries = adapter_sweep()?;
    if let Some(path) = bench::write_named_json_report("serving", "adapter_sweep", sweep_entries)? {
        println!("recorded adapter sweep -> {}", path.display());
    }

    let sampling_entries = sampling_comparison()?;
    if let Some(path) = bench::write_named_json_report("serving", "sampling", sampling_entries)? {
        println!("recorded sampled-vs-greedy comparison -> {}", path.display());
    }

    let auto = RuntimeOpts::from_env().threads;
    let mut sweep = vec![1usize];
    if auto > 1 {
        sweep.push(auto);
    }
    let mut latency_entries = Vec::new();
    for &w in &sweep {
        latency_entries.extend(run_with_workers(w)?);
    }
    if let Some(path) = bench::write_named_json_report("serving", "latency", latency_entries)? {
        println!("recorded latency percentiles -> {}", path.display());
    }
    Ok(())
}
