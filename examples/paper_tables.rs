//! Regenerate every table and figure of the paper's evaluation
//! (DESIGN.md §5 maps each to its modules). Results are printed
//! paper-style and appended to results/<name>.txt.
//!
//!   cargo run --release --example paper_tables -- <cmd> [--seeds N]
//!       [--cap N] [--epochs N] [--fast]
//!   cmd: table1 table2 table3 table4 table5 table6 table7 table12
//!        fig3 fig4 all
//!
//! Absolute numbers differ from the paper (synthetic substrate, MiniLM
//! backbones — DESIGN.md §4); the *shape* — who wins, parameter-count
//! ordering, crossovers — is the reproduction target.
//!
//! Backend note: the native backend trains the uni family, LoRA and
//! full/linear-probe rows; baseline methods whose adjoint is not yet
//! implemented natively (vera/tied/vb/lora_xs/fourierft/fastfood) are
//! skipped there and need UNI_LORA_BACKEND=pjrt + AOT artifacts.

use anyhow::Result;
use std::fmt::Write as _;
use uni_lora::config::ModelCfg;
use uni_lora::coordinator::sweep::over_seeds;
use uni_lora::coordinator::{
    evaluator, pretrain_backbone, ClsTrainer, Hyper, LmTrainer,
};
use uni_lora::coordinator::trainer::FullClsTrainer;
use uni_lora::data::{glue, instruct, math_tasks, vision};
use uni_lora::projection::properties;
use uni_lora::projection::statics::d_effective;
use uni_lora::runtime::Backend;
use uni_lora::util::cli::Args;
use uni_lora::util::{fmt_params, peak_rss_mib};

/// Whether the active backend can train a table row's method. "full"
/// is full fine-tuning (full_cls_train, method "none" under the hood).
/// Since the ProjectionOp registry redesign, `can_train` is true for
/// every registered method on the native backend — this now only
/// filters rows whose method string the registry doesn't know.
fn trainable_here(backend: &str, method: &str) -> bool {
    backend != "native"
        || method == "full"
        || uni_lora::runtime::native::can_train(method)
}

struct Ctx {
    exec: Box<dyn Backend>,
    seeds: Vec<u64>,
    cap: usize,
    epochs: usize,
    out: String,
}

impl Ctx {
    fn new(args: &Args) -> Result<Ctx> {
        let fast = args.has("fast");
        let seeds: Vec<u64> = (0..args.usize_or("seeds", if fast { 1 } else { 3 }) as u64)
            .map(|i| 41 + i)
            .collect();
        Ok(Ctx {
            exec: uni_lora::runtime::default_backend()?,
            seeds,
            cap: args.usize_or("cap", if fast { 300 } else { 800 }),
            epochs: args.usize_or("epochs", if fast { 1 } else { 2 }),
            out: String::new(),
        })
    }

    fn backbone(&mut self, size: &str) -> Result<Vec<f32>> {
        Ok(pretrain_backbone(
            self.exec.as_mut(),
            size,
            42,
            uni_lora::coordinator::backbone::default_steps(),
        )?
        .0)
    }

    fn skip(&self, method: &str) -> bool {
        !trainable_here(self.exec.name(), method)
    }

    fn emit(&mut self, line: &str) {
        println!("{line}");
        self.out.push_str(line);
        self.out.push('\n');
    }

    fn flush(&mut self, name: &str) -> Result<()> {
        std::fs::create_dir_all("results")?;
        std::fs::write(format!("results/{name}.txt"), &self.out)?;
        self.out.clear();
        Ok(())
    }

    fn hyper(&self) -> Hyper {
        Hyper { lr_theta: 5e-3, lr_head: 5e-2, wd: 0.0, epochs: self.epochs }
    }

    /// One GLUE-like fine-tune run -> metric value.
    fn glue_run(
        &mut self,
        size: &str,
        method: &str,
        task: &str,
        seed: u64,
        w0: &[f32],
    ) -> Result<f64> {
        let c = if task == "stsb" { 1 } else { 2 };
        let base = format!("glue_{size}_{method}_c{c}");
        let mut tr = ClsTrainer::new(self.exec.as_ref(), &base, seed, w0.to_vec())?;
        let split = glue::generate(task, seed, tr.cfg.seq, tr.cfg.vocab);
        let train = &split.train[..split.train.len().min(self.cap)];
        let hp = self.hyper();
        let (score, _) =
            tr.run_and_score(self.exec.as_mut(), train, &split.dev, split.metric, &hp)?;
        Ok(score)
    }
}

// ------------------------------------------------------------------ tables

fn d_of(size: &str, method: &str) -> usize {
    let mut cfg = ModelCfg::test_base(method);
    if size == "large" {
        cfg.hidden = 96;
        cfg.layers = 3;
        cfg.d = 512;
    }
    if size == "lm" {
        cfg.hidden = 128;
        cfg.layers = 4;
        cfg.d = 1024;
    }
    d_effective(&cfg)
}

fn table1(ctx: &mut Ctx) -> Result<()> {
    ctx.emit("== Table 1: properties of the projection matrices P ==");
    ctx.emit(&format!(
        "{:<12} {:<9} {:<9} {:<10} {:<9} {:<12} {:<10}",
        "Method", "LearnedP", "Global", "Uniform", "Isometry", "iso_err", "load_ratio"
    ));
    for method in ["vera", "tied", "vb", "lora_xs", "fastfood", "uni", "local", "nonuniform"] {
        let mut cfg = ModelCfg::test_base(method);
        cfg.hidden = 16;
        cfg.layers = 2;
        cfg.rank = 2;
        cfg.d = 32;
        cfg.vb_b = 16;
        cfg.vb_bank = 8;
        cfg.n_coef = 12;
        let p = properties::analyze(&cfg, 42)?;
        let yn = |b: bool| if b { "yes" } else { "no" };
        ctx.emit(&format!(
            "{:<12} {:<9} {:<9} {:<10} {:<9} {:<12.2e} {:<10.2}",
            method,
            yn(p.learned_p),
            yn(p.globality),
            yn(p.uniformity),
            yn(p.isometry),
            p.isometry_err,
            p.load_ratio
        ));
    }
    ctx.flush("table1")
}

fn table2(ctx: &mut Ctx) -> Result<()> {
    ctx.emit("== Table 2: GLUE-like suite (median over seeds, paper metric/task) ==");
    let methods = ["lora", "vera", "tied", "vb", "lora_xs", "fourierft", "uni"];
    for size in ["base", "large"] {
        let w0 = ctx.backbone(size)?;
        ctx.emit(&format!("-- backbone: {size} --"));
        ctx.emit(&format!(
            "{:<11} {:>9} {:>7} {:>7} {:>7} {:>7} {:>7} {:>7} {:>7}",
            "Method", "#Params", "SST2", "MRPC", "COLA", "QNLI", "RTE", "STSB", "Avg"
        ));
        for method in methods {
            if ctx.skip(method) {
                ctx.emit(&format!(
                    "{:<11} {:>9}   (skipped: needs pjrt backend)",
                    method,
                    fmt_params(d_of(size, method))
                ));
                continue;
            }
            let mut row = format!("{:<11} {:>9}", method, fmt_params(d_of(size, method)));
            let mut scores = Vec::new();
            for task in glue::TASKS {
                let seeds = ctx.seeds.clone();
                let s = over_seeds(&seeds, |seed| ctx.glue_run(size, method, task, seed, &w0))?;
                let scaled = 100.0 * s.median;
                scores.push(scaled);
                let _ = write!(row, " {scaled:>7.1}");
            }
            let avg = scores.iter().sum::<f64>() / scores.len() as f64;
            let _ = write!(row, " {avg:>7.1}");
            ctx.emit(&row);
        }
    }
    ctx.flush("table2")
}

fn lm_run(
    ctx: &mut Ctx,
    base: &str,
    seed: u64,
    w0: &[f32],
    task: &str,
) -> Result<(f64, f64, f64)> {
    // returns (metric1, metric2, train_secs)
    let mut tr = LmTrainer::new(ctx.exec.as_ref(), base, seed, w0.to_vec())?;
    let seq = tr.cfg.seq;
    let hp = Hyper { lr_theta: 2e-3, lr_head: 0.0, wd: 0.0, epochs: ctx.epochs };
    if task == "math" {
        let (split, dev_math) = math_tasks::generate(seed, seq, ctx.cap, 64);
        let rr = tr.train(ctx.exec.as_mut(), &split.train, &hp)?;
        let gsm = evaluator::exact_match_accuracy(&mut tr, ctx.exec.as_mut(), &split.dev, 8)?;
        let mth = evaluator::exact_match_accuracy(&mut tr, ctx.exec.as_mut(), &dev_math, 8)?;
        Ok((gsm, mth, rr.train_secs))
    } else {
        let (split, dev2) = instruct::generate(seed, seq, ctx.cap, 48);
        let rr = tr.train(ctx.exec.as_mut(), &split.train, &hp)?;
        let s1 = evaluator::rubric_score(&mut tr, ctx.exec.as_mut(), &split.dev, 10)?;
        let s2 = evaluator::rubric_score(&mut tr, ctx.exec.as_mut(), &dev2, 10)?;
        Ok((s1, s2, rr.train_secs))
    }
}

fn table3(ctx: &mut Ctx) -> Result<()> {
    ctx.emit("== Table 3: math reasoning (exact-match %, GSM8K-like / MATH-like) ==");
    let w0 = ctx.backbone("lm")?;
    ctx.emit(&format!("{:<12} {:>9} {:>9} {:>9}", "Method", "#Params", "GSM8K", "MATH"));
    for method in ["lora", "lora_xs", "vb", "vera", "fourierft", "uni"] {
        if ctx.skip(method) {
            ctx.emit(&format!(
                "{:<12} {:>9}   (skipped: needs pjrt backend)",
                method,
                fmt_params(d_of("lm", method))
            ));
            continue;
        }
        let seed = ctx.seeds[0];
        let (g, m, _) = lm_run(ctx, &format!("lm_{method}"), seed, &w0, "math")?;
        ctx.emit(&format!(
            "{:<12} {:>9} {:>9.2} {:>9.2}",
            method,
            fmt_params(d_of("lm", method)),
            g,
            m
        ));
    }
    ctx.flush("table3")
}

fn table4(ctx: &mut Ctx) -> Result<()> {
    ctx.emit("== Table 4: instruction tuning (rubric judge, Score1/Score2) ==");
    let w0 = ctx.backbone("lm")?;
    ctx.emit(&format!("{:<14} {:>9} {:>8} {:>8}", "Method", "#Params", "Score1", "Score2"));
    // w/o FT baseline: untrained adapter
    {
        let seed = ctx.seeds[0];
        let mut tr = LmTrainer::new(ctx.exec.as_ref(), "lm_uni", seed, w0.clone())?;
        let (split, dev2) = instruct::generate(seed, tr.cfg.seq, 10, 48);
        let s1 = evaluator::rubric_score(&mut tr, ctx.exec.as_mut(), &split.dev, 10)?;
        let s2 = evaluator::rubric_score(&mut tr, ctx.exec.as_mut(), &dev2, 10)?;
        ctx.emit(&format!("{:<14} {:>9} {:>8.2} {:>8.2}", "w/o FT", "-", s1, s2));
    }
    for (label, method, base, d) in [
        ("lora(r64)", "lora", "lm_lora_r64", 8 * 2 * 128 * 64),
        ("vb", "vb", "lm_vb", d_of("lm", "vb")),
        ("uni", "uni", "lm_uni", d_of("lm", "uni")),
    ] {
        if ctx.skip(method) {
            ctx.emit(&format!("{label:<14} {:>9}   (skipped: needs pjrt backend)", fmt_params(d)));
            continue;
        }
        let seed = ctx.seeds[0];
        let (s1, s2, _) = lm_run(ctx, base, seed, &w0, "instruct")?;
        ctx.emit(&format!("{:<14} {:>9} {:>8.2} {:>8.2}", label, fmt_params(d), s1, s2));
    }
    ctx.flush("table4")
}

fn table5(ctx: &mut Ctx) -> Result<()> {
    ctx.emit("== Table 5: vision suite (accuracy %, 8 synthetic datasets) ==");
    for size in ["base", "large"] {
        let w0 = ctx.backbone(size)?;
        ctx.emit(&format!("-- ViT-{size} --"));
        let mut header = format!("{:<11} {:>9}", "Method", "#Params");
        for ds in vision::DATASETS {
            let _ = write!(header, " {:>7}", &ds[..ds.len().min(7)]);
        }
        header.push_str("     Avg");
        ctx.emit(&header);
        for method in ["none", "full", "fourierft", "uni"] {
            if ctx.skip(method) {
                ctx.emit(&format!("{method:<11}           (skipped: needs pjrt backend)"));
                continue;
            }
            let params = match method {
                "none" => 0,
                "full" => {
                    ctx.exec.meta(&format!("vit_{size}_full_full_cls_train"))?.base_params
                }
                m => d_of(size, m),
            };
            let mut row = format!(
                "{:<11} {:>9}",
                match method {
                    "none" => "LP",
                    "full" => "FF",
                    m => m,
                },
                if params == 0 { "-".to_string() } else { fmt_params(params) }
            );
            let mut scores = Vec::new();
            for ds in vision::DATASETS {
                let seed = ctx.seeds[0];
                let split = vision::generate(ds, seed, 32, 512);
                let cap = ctx.cap.min(split.train.len());
                let hp = ctx.hyper();
                let score = if method == "full" {
                    let mut tr = FullClsTrainer::new(
                        ctx.exec.as_ref(),
                        &format!("vit_{size}_full"),
                        &format!("vit_{size}_none_cls_eval"),
                        seed,
                        w0.clone(),
                    )?;
                    let hp = Hyper { lr_theta: 1e-3, ..hp };
                    tr.run_and_score(ctx.exec.as_mut(), &split.train[..cap], &split.dev, "acc", &hp)?
                        .0
                } else {
                    let mut tr = ClsTrainer::new(
                        ctx.exec.as_ref(),
                        &format!("vit_{size}_{method}"),
                        seed,
                        w0.clone(),
                    )?;
                    tr.run_and_score(ctx.exec.as_mut(), &split.train[..cap], &split.dev, "acc", &hp)?
                        .0
                };
                scores.push(100.0 * score);
                let _ = write!(row, " {:>7.1}", 100.0 * score);
            }
            let avg = scores.iter().sum::<f64>() / scores.len() as f64;
            let _ = write!(row, " {avg:>7.1}");
            ctx.emit(&row);
        }
    }
    ctx.flush("table5")
}

fn table6(ctx: &mut Ctx) -> Result<()> {
    ctx.emit("== Table 6: Uni-LoRA vs Fastfood (score %, train seconds) ==");
    let w0 = ctx.backbone("large")?;
    ctx.emit(&format!("{:<7} {:<10} {:>8} {:>10}", "Task", "Method", "Score", "Time(s)"));
    for task in ["mrpc", "cola", "sst2", "qnli"] {
        for method in ["uni", "fastfood"] {
            if ctx.skip(method) {
                ctx.emit(&format!("{task:<7} {method:<10}   (skipped: needs pjrt backend)"));
                continue;
            }
            let seed = ctx.seeds[0];
            let base = format!("glue_large_{method}_c2");
            let mut tr = ClsTrainer::new(ctx.exec.as_ref(), &base, seed, w0.clone())?;
            let split = glue::generate(task, seed, tr.cfg.seq, tr.cfg.vocab);
            let train = &split.train[..split.train.len().min(ctx.cap)];
            let hp = ctx.hyper();
            let (score, rr) =
                tr.run_and_score(ctx.exec.as_mut(), train, &split.dev, split.metric, &hp)?;
            ctx.emit(&format!(
                "{:<7} {:<10} {:>8.1} {:>10.1}",
                task, method, 100.0 * score, rr.train_secs
            ));
        }
    }
    ctx.flush("table6")
}

fn table7(ctx: &mut Ctx) -> Result<()> {
    ctx.emit("== Table 7: global vs local vs non-uniform projection (score %) ==");
    let w0 = ctx.backbone("large")?;
    ctx.emit(&format!(
        "{:<7} {:>10} {:>10} {:>12}",
        "Task", "Uni-LoRA", "Local", "Non-uniform"
    ));
    for task in ["mrpc", "cola", "sst2", "qnli"] {
        let mut vals = Vec::new();
        for method in ["uni", "local", "nonuniform"] {
            let seeds = ctx.seeds.clone();
            let s = over_seeds(&seeds, |seed| {
                ctx.glue_run("large", method, task, seed, &w0)
            })?;
            vals.push(100.0 * s.median);
        }
        ctx.emit(&format!(
            "{:<7} {:>10.1} {:>10.1} {:>12.1}",
            task, vals[0], vals[1], vals[2]
        ));
    }
    ctx.flush("table7")
}

fn table12(ctx: &mut Ctx) -> Result<()> {
    ctx.emit("== Table 12: LoRA rank 64 vs rank 4 vs Uni-LoRA (instruct) ==");
    let w0 = ctx.backbone("lm")?;
    ctx.emit(&format!(
        "{:<14} {:>9} {:>8} {:>10} {:>10}",
        "Method", "#Params", "Score1", "Time(s)", "PeakRSS(MiB)"
    ));
    for (label, base, d) in [
        ("lora(r64)", "lm_lora_r64", 8usize * 2 * 128 * 64),
        ("lora(r4)", "lm_lora", d_of("lm", "lora")),
        ("uni(r4)", "lm_uni", d_of("lm", "uni")),
    ] {
        let seed = ctx.seeds[0];
        let (s1, _s2, secs) = lm_run(ctx, base, seed, &w0, "instruct")?;
        ctx.emit(&format!(
            "{:<14} {:>9} {:>8.2} {:>10.1} {:>10.0}",
            label,
            fmt_params(d),
            s1,
            secs,
            peak_rss_mib()
        ));
    }
    ctx.flush("table12")
}

fn fig3(ctx: &mut Ctx) -> Result<()> {
    ctx.emit("== Figure 3: accuracy vs subspace dimension d ==");
    let w0 = ctx.backbone("base")?;
    ctx.emit("d, sst2_acc");
    for (d, base) in [
        (16, "fig3_base_uni_d16"),
        (64, "fig3_base_uni_d64"),
        (256, "glue_base_uni_c2"),
        (1024, "fig3_base_uni_d1024"),
    ] {
        let seed = ctx.seeds[0];
        let mut tr =
            ClsTrainer::new(ctx.exec.as_ref(), base.trim_end_matches("_cls_train"), seed, w0.clone())?;
        let split = glue::generate("sst2", seed, tr.cfg.seq, tr.cfg.vocab);
        let train = &split.train[..split.train.len().min(ctx.cap)];
        let hp = ctx.hyper();
        let (score, _) = tr.run_and_score(ctx.exec.as_mut(), train, &split.dev, "acc", &hp)?;
        ctx.emit(&format!("{d}, {:.1}", 100.0 * score));
    }
    let w0lm = ctx.backbone("lm")?;
    ctx.emit("d, gsm8k_em, math_em");
    for (d, base) in [
        (256, "fig3_lm_uni_d256"),
        (1024, "lm_uni"),
        (4096, "fig3_lm_uni_d4096"),
    ] {
        let seed = ctx.seeds[0];
        let (g, m, _) = lm_run(ctx, base, seed, &w0lm, "math")?;
        ctx.emit(&format!("{d}, {g:.2}, {m:.2}"));
    }
    ctx.flush("fig3")
}

fn fig4(ctx: &mut Ctx) -> Result<()> {
    ctx.emit("== Figure 4: accuracy vs LoRA rank r (d fixed) ==");
    let w0 = ctx.backbone("base")?;
    ctx.emit("r, sst2_acc");
    for (r, base) in [
        (1, "fig4_base_uni_r1"),
        (2, "fig4_base_uni_r2"),
        (4, "fig4_base_uni_r4"),
        (8, "fig4_base_uni_r8"),
    ] {
        let seed = ctx.seeds[0];
        let mut tr = ClsTrainer::new(ctx.exec.as_ref(), base, seed, w0.clone())?;
        let split = glue::generate("sst2", seed, tr.cfg.seq, tr.cfg.vocab);
        let train = &split.train[..split.train.len().min(ctx.cap)];
        let hp = ctx.hyper();
        let (score, _) = tr.run_and_score(ctx.exec.as_mut(), train, &split.dev, "acc", &hp)?;
        ctx.emit(&format!("{r}, {:.1}", 100.0 * score));
    }
    ctx.flush("fig4")
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_else(|| "all".into());
    let mut ctx = Ctx::new(&args)?;
    let t0 = std::time::Instant::now();
    match cmd.as_str() {
        "table1" => table1(&mut ctx)?,
        "table2" => table2(&mut ctx)?,
        "table3" => table3(&mut ctx)?,
        "table4" => table4(&mut ctx)?,
        "table5" => table5(&mut ctx)?,
        "table6" => table6(&mut ctx)?,
        "table7" => table7(&mut ctx)?,
        "table12" => table12(&mut ctx)?,
        "fig3" => fig3(&mut ctx)?,
        "fig4" => fig4(&mut ctx)?,
        "all" => {
            table1(&mut ctx)?;
            table2(&mut ctx)?;
            table3(&mut ctx)?;
            table4(&mut ctx)?;
            table5(&mut ctx)?;
            table6(&mut ctx)?;
            table7(&mut ctx)?;
            table12(&mut ctx)?;
            fig3(&mut ctx)?;
            fig4(&mut ctx)?;
        }
        other => anyhow::bail!("unknown command {other:?}"),
    }
    println!(
        "\n[done in {:.1}s, exec stats: {:?}]",
        t0.elapsed().as_secs_f64(),
        ctx.exec.stats()
    );
    Ok(())
}
