//! Multi-adapter serving demo: train several one-vector adapters
//! (math + instruction variants), register them, start the server, and
//! fire a mixed workload from concurrent clients — then print router
//! stats showing the continuous-batching serving metrics (tokens/s,
//! TTFT, reconstruction-cache hit rate, decode-slot occupancy).
//!
//!   cargo run --release --example adapter_server -- [--requests 48]
//!
//! Runs on the native backend by default (UNI_LORA_BACKEND=pjrt to use
//! AOT artifacts instead).

use anyhow::Result;
use std::sync::Arc;
use uni_lora::adapters::{AdapterCheckpoint, Registry};
use uni_lora::coordinator::{pretrain_backbone, Hyper, LmTrainer};
use uni_lora::data::{instruct, math_tasks, vocab};
use uni_lora::runtime::Backend;
use uni_lora::server::server::Client;
use uni_lora::server::{serve, ServerConfig};
use uni_lora::util::cli::Args;

fn train_adapter(
    exec: &mut dyn Backend,
    w0: &[f32],
    seed: u64,
    task: &str,
) -> Result<AdapterCheckpoint> {
    let mut tr = LmTrainer::new(exec, "lm_uni", seed, w0.to_vec())?;
    let hp = Hyper { lr_theta: 2e-3, lr_head: 0.0, wd: 0.0, epochs: 1 };
    let seq = tr.cfg.seq;
    match task {
        "math" => {
            let (split, _) = math_tasks::generate(seed, seq, 300, 8);
            tr.train(exec, &split.train, &hp)?;
        }
        _ => {
            let (split, _) = instruct::generate(seed, seq, 300, 8);
            tr.train(exec, &split.train, &hp)?;
        }
    }
    Ok(AdapterCheckpoint {
        seed,
        method: "uni".into(),
        artifact: "lm_uni_lm_logits".into(),
        theta: tr.theta.clone(),
        head: vec![],
    })
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let n_requests = args.usize_or("requests", 48);
    let mut exec = uni_lora::runtime::default_backend()?;
    println!("[setup] backend: {}", exec.name());
    let (w0, _) = pretrain_backbone(
        exec.as_mut(),
        "lm",
        42,
        uni_lora::coordinator::backbone::default_steps(),
    )?;

    println!("[setup] training 3 one-vector adapters...");
    let registry = Registry::new();
    registry.insert("math-a".into(), train_adapter(exec.as_mut(), &w0, 1, "math")?);
    registry.insert("math-b".into(), train_adapter(exec.as_mut(), &w0, 2, "math")?);
    registry.insert("instruct".into(), train_adapter(exec.as_mut(), &w0, 3, "instruct")?);
    println!(
        "[setup] registry holds {} adapters in {} bytes total",
        registry.len(),
        registry.resident_bytes()
    );

    let cfg = exec.meta("lm_uni_lm_logits")?.cfg.clone();
    exec.prepare("lm_uni_lm_logits")?;
    let handle = serve(
        ServerConfig::new("127.0.0.1:0", "lm_uni_lm_logits"),
        exec,
        Arc::new(registry),
        cfg,
        w0,
    )?;
    println!("[serve] listening on {}", handle.addr);

    // mixed workload from 4 concurrent client threads
    let t0 = std::time::Instant::now();
    let addr = handle.addr;
    let mut joins = Vec::new();
    for c in 0..4u64 {
        joins.push(std::thread::spawn(move || -> Result<usize> {
            let mut client = Client::connect(addr)?;
            let adapters = ["math-a", "math-b", "instruct"];
            let mut ok = 0;
            for i in 0..(n_requests / 4) {
                let adapter = adapters[(c as usize + i) % 3];
                let a = 1 + ((c + i as u64) % 8) as u32;
                let b = 1 + ((c * 3 + i as u64) % 8) as u32;
                let prompt = vec![
                    vocab::BOS, vocab::Q_MARKER, vocab::digit(a), vocab::PLUS,
                    vocab::digit(b), vocab::EQUALS, vocab::A_MARKER,
                ];
                let toks = client.generate(adapter, prompt, 4)?;
                if vocab::decode_number(&toks) == Some((a + b) as u64) {
                    ok += 1;
                }
            }
            Ok(ok)
        }));
    }
    let mut correct = 0;
    for j in joins {
        correct += j.join().unwrap()?;
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut client = Client::connect(handle.addr)?;
    let stats = client.stats()?;
    println!(
        "[load] {n_requests} requests in {wall:.2}s ({:.1} req/s), \
         {correct} arithmetically correct",
        n_requests as f64 / wall
    );
    println!("[router] {}", stats.to_string());
    handle.shutdown();
    Ok(())
}
