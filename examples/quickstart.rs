//! Quickstart: the whole Uni-LoRA story in one minute.
//!
//!   cargo run --release --example quickstart
//!
//! Runs on the pure-Rust native backend out of the box (no artifacts,
//! no Python); set UNI_LORA_BACKEND=pjrt after `make artifacts` to use
//! the PJRT path instead.
//!
//! 1. pretrain (or load) a small backbone — in-system "foundation model"
//! 2. fine-tune a Uni-LoRA adapter (one vector!) on a sentiment task
//! 3. save the adapter as seed + theta_d, print its size
//! 4. reload it, expand DeltaW in pure Rust, and re-evaluate

use anyhow::Result;
use uni_lora::adapters::AdapterCheckpoint;
use uni_lora::coordinator::{pretrain_backbone, ClsTrainer, Hyper};
use uni_lora::data::glue;
use uni_lora::metrics;
use uni_lora::runtime::Backend;
use uni_lora::util::fmt_params;

fn main() -> Result<()> {
    let mut exec = uni_lora::runtime::default_backend()?;
    println!("[0/4] backend: {}", exec.name());

    // 1. backbone
    let (w0, curve) = pretrain_backbone(
        exec.as_mut(),
        "base",
        42,
        uni_lora::coordinator::backbone::default_steps(),
    )?;
    if curve.is_empty() {
        println!("[1/4] backbone loaded from cache ({} params)", fmt_params(w0.len()));
    } else {
        println!(
            "[1/4] pretrained backbone: LM loss {:.3} -> {:.3} over {} steps",
            curve[0],
            curve.last().unwrap(),
            curve.len()
        );
    }

    // 2. fine-tune Uni-LoRA on the SST-2-like task
    let seed = 7;
    let mut tr = ClsTrainer::new(exec.as_ref(), "glue_base_uni_c2", seed, w0)?;
    let split = glue::generate("sst2", seed, tr.cfg.seq, tr.cfg.vocab);
    let hp = Hyper { lr_theta: 5e-3, lr_head: 5e-2, wd: 0.0, epochs: 2 };
    let (acc, rr) =
        tr.run_and_score(exec.as_mut(), &split.train[..800], &split.dev, "acc", &hp)?;
    println!(
        "[2/4] fine-tuned d={} adapter: sst2 accuracy {:.1}% ({} steps, {:.1}s)",
        tr.theta.len(),
        100.0 * acc,
        rr.steps,
        rr.train_secs
    );

    // 3. the paper's storage claim: the adapter is seed + one vector
    let ckpt = AdapterCheckpoint {
        seed,
        method: "uni".into(),
        artifact: "glue_base_uni_c2_cls_eval".into(),
        theta: tr.theta.clone(),
        head: tr.head.clone(),
    };
    let path = std::env::temp_dir().join("quickstart_adapter.uni1");
    ckpt.save(&path)?;
    println!(
        "[3/4] adapter saved: {} bytes for d={} (+head {}) — one vector is all you need",
        ckpt.byte_size(),
        ckpt.d(),
        ckpt.head.len()
    );

    // 4. reload and verify: same predictions from (seed, theta) alone
    let loaded = AdapterCheckpoint::load(&path)?;
    assert_eq!(loaded, ckpt);
    let mut tr2 = ClsTrainer::new(exec.as_ref(), "glue_base_uni_c2", loaded.seed, tr.w0.clone())?;
    tr2.theta = loaded.theta;
    tr2.head = loaded.head;
    let logits = tr2.eval_logits(exec.as_mut(), &split.dev)?;
    let order = uni_lora::data::batcher::shuffled_indices(split.dev.len(), 0, 0);
    let labels: Vec<f32> = order.iter().map(|&i| split.dev[i].label).collect();
    let acc2 = metrics::compute("acc", &logits, &labels);
    println!("[4/4] reloaded adapter re-evaluates to {:.1}% — roundtrip exact", 100.0 * acc2);
    assert!((acc2 - acc).abs() < 1e-9, "adapter roundtrip changed predictions");
    std::fs::remove_file(path).ok();
    Ok(())
}
