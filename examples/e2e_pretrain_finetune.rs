//! End-to-end driver (DESIGN.md §5 "e2e"): proves all layers compose on
//! a real (small) workload.
//!
//!   cargo run --release --example e2e_pretrain_finetune -- [--steps 300]
//!       [--size lm|e2e] [--ft-examples 400]
//!
//! 1. Pretrain a decoder LM from scratch on the synthetic corpus,
//!    logging the loss curve (recorded in EXPERIMENTS.md). `--size e2e`
//!    uses the ~7M-param backbone; `lm` (default) the ~0.7M one so the
//!    default run finishes in minutes on one CPU core.
//! 2. Fine-tune a Uni-LoRA adapter for math reasoning.
//! 3. Evaluate exact-match via Rust-side batched greedy decoding.
//! 4. Save the adapter, reload, and serve one request through the
//!    in-process router — the full request path, Python-free.
//!
//! Backend: native by default; UNI_LORA_BACKEND=pjrt for AOT artifacts.

use anyhow::Result;
use std::sync::Arc;
use uni_lora::adapters::{AdapterCheckpoint, Registry};
use uni_lora::coordinator::{evaluator, pretrain_backbone, Hyper, LmTrainer};
use uni_lora::data::{math_tasks, vocab};
use uni_lora::runtime::Backend;
use uni_lora::server::server::Client;
use uni_lora::server::{serve, ServerConfig};
use uni_lora::util::cli::Args;
use uni_lora::util::fmt_params;

fn main() -> Result<()> {
    let args = Args::from_env();
    let size = args.get_or("size", "lm");
    let steps = args.usize_or("steps", 300);
    let n_ft = args.usize_or("ft-examples", 400);
    let base = if size == "e2e" { "e2e_uni".to_string() } else { "lm_uni".to_string() };

    let mut exec = uni_lora::runtime::default_backend()?;
    println!("[backend] {}", exec.name());
    let t0 = std::time::Instant::now();

    // ---- 1. pretraining ----
    let (w0, curve) = pretrain_backbone(exec.as_mut(), &size, 42, steps)?;
    if curve.is_empty() {
        println!("[pretrain] loaded cached '{size}' backbone ({} params)", fmt_params(w0.len()));
    } else {
        println!(
            "[pretrain] {} params, {} steps — loss curve:",
            fmt_params(w0.len()),
            curve.len()
        );
        for (i, l) in curve.iter().enumerate() {
            if i % 25 == 0 || i + 1 == curve.len() {
                println!("  step {:>4}: loss {:.4}", i + 1, l);
            }
        }
    }

    // ---- 2. Uni-LoRA fine-tuning ----
    let seed = 11;
    let mut tr = LmTrainer::new(exec.as_ref(), &base, seed, w0.clone())?;
    let seq = tr.cfg.seq;
    let (split, dev_math) = math_tasks::generate(seed, seq, n_ft, 64);
    let hp = Hyper { lr_theta: 2e-3, lr_head: 0.0, wd: 0.0, epochs: 2 };
    let rr = tr.train(exec.as_mut(), &split.train, &hp)?;
    println!(
        "[finetune] d={} adapter on {} examples: loss {:.3} -> {:.3} ({} steps, {:.1}s)",
        tr.theta.len(),
        split.train.len(),
        rr.losses[0],
        rr.losses.last().unwrap(),
        rr.steps,
        rr.train_secs
    );

    // ---- 3. generation eval ----
    let gsm = evaluator::exact_match_accuracy(&mut tr, exec.as_mut(), &split.dev, 8)?;
    let mth = evaluator::exact_match_accuracy(&mut tr, exec.as_mut(), &dev_math, 8)?;
    println!("[eval] exact-match: GSM8K-like {gsm:.1}%  MATH-like {mth:.1}%");

    // ---- 4. save adapter + serve one request through the router ----
    let dir = std::env::temp_dir().join("e2e_adapters");
    std::fs::create_dir_all(&dir)?;
    let ckpt = AdapterCheckpoint {
        seed,
        method: "uni".into(),
        artifact: format!("{base}_lm_logits"),
        theta: tr.theta.clone(),
        head: vec![],
    };
    ckpt.save(dir.join("math.uni1"))?;
    println!("[adapter] saved ({} bytes — seed + one vector)", ckpt.byte_size());

    let cfg = exec.meta(&format!("{base}_lm_logits"))?.cfg.clone();
    let registry = Arc::new(Registry::load_dir(&dir)?);
    let handle = serve(
        ServerConfig::new("127.0.0.1:0", format!("{base}_lm_logits")),
        exec,
        registry,
        cfg,
        w0,
    )?;
    let mut client = Client::connect(handle.addr)?;
    // ask the served adapter: 3 + 4 = ?
    let mut prompt = vec![vocab::BOS, vocab::Q_MARKER, vocab::digit(3), vocab::PLUS,
                          vocab::digit(4), vocab::EQUALS, vocab::A_MARKER];
    // keep prompt format identical to training examples
    prompt.truncate(7);
    let toks = client.generate("math", prompt, 4)?;
    println!(
        "[serve] 3+4 -> generated {:?} (decoded: {:?})",
        toks,
        vocab::decode_number(&toks)
    );
    let stats = client.stats()?;
    println!("[serve] router stats: {}", stats.to_string());
    handle.shutdown();
    std::fs::remove_dir_all(dir).ok();
    println!("[e2e] complete in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
