"""SplitMix64 stream tests — the cross-language contract.

GOLDEN_SEED42 is asserted verbatim by rust/src/rng.rs tests; if either
side drifts, adapters stop being reconstructible from (seed, theta_d).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import unirng as rng


def test_golden_seed42():
    got = [int(x) for x in rng.u64_stream(42, 4)]
    assert got == rng.GOLDEN_SEED42


def test_stream_deterministic_and_extendable():
    a = rng.u64_stream(7, 100)
    b = rng.u64_stream(7, 1000)
    assert np.array_equal(a, b[:100])


def test_child_seeds_distinct():
    seeds = {rng.child_seed(42, s) for s in range(64)}
    assert len(seeds) == 64


@given(st.integers(0, 2**32), st.integers(1, 2**20))
@settings(max_examples=50, deadline=None)
def test_indices_in_range(seed, d):
    idx = rng.indices(seed, 257, d)
    assert idx.min() >= 0 and idx.max() < d


@given(st.integers(0, 2**32))
@settings(max_examples=25, deadline=None)
def test_uniform01_range(seed):
    u = rng.uniform01(seed, 512)
    assert (u >= 0).all() and (u < 1).all()


def test_normals_moments():
    z = rng.normals(123, 200_000)
    assert abs(z.mean()) < 0.01
    assert abs(z.std() - 1.0) < 0.01


def test_signs_balanced():
    s = rng.signs(5, 100_000)
    assert set(np.unique(s)) == {-1.0, 1.0}
    assert abs(s.mean()) < 0.02


@given(st.integers(0, 2**32), st.integers(1, 300))
@settings(max_examples=40, deadline=None)
def test_permutation_is_permutation(seed, n):
    p = rng.permutation(seed, n)
    assert sorted(p.tolist()) == list(range(n))


def test_uniform_range_bounds():
    u = rng.uniform_range(9, 10_000, -0.02, 0.02)
    assert u.min() >= -0.02 and u.max() < 0.02
    assert u.dtype == np.float32
