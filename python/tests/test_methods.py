"""The unified framework: layouts, statics, per-method apply semantics,
and the Table-1 properties (isometry / uniformity) of our projection."""
import jax.numpy as jnp
import numpy as np
import pytest

from compile import methods, unirng as rng
from compile.configs import BASE, ModelCfg, with_method

ALL_METHODS = ["lora", "uni", "local", "nonuniform", "fastfood", "vera",
               "tied", "vb", "lora_xs", "fourierft", "none"]


def mk(method, **kw):
    return with_method(BASE, method, **kw)


@pytest.mark.parametrize("m", ALL_METHODS)
def test_layout_and_statics_consistent(m):
    cfg = mk(m)
    segs = methods.theta_segments(cfg)
    d = methods.d_effective(cfg)
    assert d >= 1
    th = methods.init_theta(cfg, seed=42)
    assert th.shape == (d,)
    stats = methods.gen_statics(cfg, seed=42)
    spec = methods.statics_spec(cfg)
    assert set(stats.keys()) == {n for n, _, _ in spec}
    for name, dt, shape in spec:
        assert stats[name].shape == tuple(shape), name
        want = np.int32 if dt == "i32" else np.float32
        assert stats[name].dtype == want, (name, stats[name].dtype)


def test_param_efficiency_ordering():
    """The paper's headline: uni trains far fewer params than lora,
    fewer than vera/tied; lora == D."""
    d_of = lambda m, **kw: methods.d_effective(mk(m, **kw))
    assert d_of("lora") == BASE.d_full
    assert d_of("uni") == BASE.d
    assert d_of("uni") < d_of("vera") < d_of("tied") < d_of("lora")
    assert d_of("lora_xs") == BASE.n_modules * BASE.rank ** 2


def test_uni_projection_isometry():
    """Theorem 1: P^T P = I for the uniform random one-hot projection."""
    cfg = mk("uni", d=64)
    s = methods.gen_statics(cfg, seed=7)
    idx, nrm = s["idx"], s["nrm"]
    D, d = len(idx), 64
    P = np.zeros((D, d), np.float64)
    P[np.arange(D), idx] = nrm
    np.testing.assert_allclose(P.T @ P, np.eye(d), atol=1e-6)
    # isometry on random vectors
    for seed in range(5):
        x = rng.normals(100 + seed, d)
        np.testing.assert_allclose(
            np.linalg.norm(P @ x), np.linalg.norm(x), rtol=1e-5
        )


def test_uni_projection_uniformity():
    """Load balance: column occupancy is within a tight band of D/d."""
    cfg = mk("uni", d=64)
    s = methods.gen_statics(cfg, seed=3)
    cnt = np.bincount(s["idx"], minlength=64)
    mean = cfg.d_full / 64
    assert cnt.min() > 0.3 * mean and cnt.max() < 2.5 * mean


def test_local_projection_is_layerwise():
    cfg = mk("local", d=64)
    s = methods.gen_statics(cfg, seed=3)
    per_layer = 2 * cfg.module_len
    dl = 64 // cfg.layers
    for l in range(cfg.layers):
        chunk = s["idx"][l * per_layer:(l + 1) * per_layer]
        assert chunk.min() >= l * dl and chunk.max() < (l + 1) * dl


def test_nonuniform_projection_splits_a_b():
    cfg = mk("nonuniform", d=66)
    s = methods.gen_statics(cfg, seed=3)
    da = 2 * 66 // 3
    ml, ar = cfg.module_len, cfg.hidden * cfg.rank
    for i in range(cfg.n_modules):
        o = i * ml
        assert s["idx"][o:o + ar].max() < da          # A rows
        assert s["idx"][o + ar:o + ml].min() >= da    # B rows


@pytest.mark.parametrize("m", ["lora", "vera", "lora_xs", "fourierft"])
def test_zero_init_methods_start_at_base(m):
    """Methods whose trainable part zero-initializes must produce
    y == x @ W0 at step 0 (DeltaW = 0)."""
    cfg = mk(m)
    th = jnp.asarray(methods.init_theta(cfg, seed=1))
    tm = methods.unflatten(th, methods.theta_segments(cfg))
    stats = {k: jnp.asarray(v) for k, v in methods.gen_statics(cfg, seed=1).items()}
    x = jnp.asarray(rng.normals(5, 8 * cfg.hidden).reshape(8, cfg.hidden))
    w0 = jnp.asarray(rng.normals(6, cfg.hidden ** 2).reshape(cfg.hidden, cfg.hidden))
    y = methods.apply(cfg, tm, stats, 0, x, w0)
    np.testing.assert_allclose(y, x @ w0, atol=1e-5)


@pytest.mark.parametrize("m", [m for m in ALL_METHODS if m != "none"])
def test_apply_shape_and_finite(m):
    cfg = mk(m)
    th = jnp.asarray(methods.init_theta(cfg, seed=2))
    tm = methods.unflatten(th, methods.theta_segments(cfg)) \
        if methods.theta_segments(cfg) else {}
    stats = {k: jnp.asarray(v) for k, v in methods.gen_statics(cfg, seed=2).items()}
    x = jnp.asarray(rng.normals(5, 2 * 3 * cfg.hidden).reshape(2, 3, cfg.hidden))
    w0 = jnp.asarray(rng.normals(6, cfg.hidden ** 2).reshape(cfg.hidden, cfg.hidden))
    for mod_i in (0, cfg.n_modules - 1):
        y = methods.apply(cfg, tm, stats, mod_i, x, w0)
        assert y.shape == x.shape
        assert bool(jnp.isfinite(y).all())


def test_vb_admixture_semantics():
    """VB sub-vectors are the top-K weighted bank rows."""
    cfg = mk("vb")
    th = jnp.asarray(methods.init_theta(cfg, seed=4))
    tm = methods.unflatten(th, methods.theta_segments(cfg))
    stats = methods.gen_statics(cfg, seed=4)
    ti = stats["top_idx"]
    bank, coef = np.asarray(tm["bank"]), np.asarray(tm["coef"])
    n_sub_mod = cfg.module_len // cfg.vb_b
    sv0 = sum(coef[0, k] * bank[ti[0, k]] for k in range(cfg.vb_k))
    x = jnp.eye(cfg.hidden)[:1]  # e_0 row
    w0 = jnp.zeros((cfg.hidden, cfg.hidden))
    y = methods.apply(cfg, tm, {k: jnp.asarray(v) for k, v in stats.items()}, 0, x, w0)
    # flat[:h*r] is A (row-major [h, r]); row 0 of A = flat[:r]
    a_row0 = np.concatenate([sv0, np.zeros(1)])[: cfg.rank]
    # y = scale * (e0 @ A) @ B; just check it is finite and nonzero
    assert np.isfinite(np.asarray(y)).all()
    assert np.abs(np.asarray(y)).sum() > 0


def test_statics_deterministic_in_seed():
    cfg = mk("uni")
    a = methods.gen_statics(cfg, seed=9)
    b = methods.gen_statics(cfg, seed=9)
    c = methods.gen_statics(cfg, seed=10)
    assert np.array_equal(a["idx"], b["idx"])
    assert not np.array_equal(a["idx"], c["idx"])


def test_init_theta_respects_specs():
    cfg = mk("vera")
    th = methods.init_theta(cfg, seed=11)
    nm, h, r = cfg.n_modules, cfg.hidden, cfg.rank
    lamb_b = th[: nm * h]
    lamb_d = th[nm * h:]
    assert np.all(lamb_b == 0.0)
    assert np.allclose(lamb_d, 0.1)


def test_lora_xs_bases_orthonormal():
    """SVD-substitute frozen bases must be orthonormal (Table 1 isometry)."""
    cfg = mk("lora_xs")
    s = methods.gen_statics(cfg, seed=5)
    for i in range(cfg.n_modules):
        pa = s["pa_t"][i]          # [h, r] orthonormal columns
        np.testing.assert_allclose(pa.T @ pa, np.eye(cfg.rank), atol=1e-5)
        pb = s["pb_t"][i]          # [r, h] orthonormal rows
        np.testing.assert_allclose(pb @ pb.T, np.eye(cfg.rank), atol=1e-5)


def test_uni_resampling_guarantees_full_support():
    """Paper footnote 1: no empty columns after resampling."""
    for seed in range(8):
        cfg = mk("uni", d=512)  # D/d = 4: empties likely per attempt
        s = methods.gen_statics(cfg, seed=seed)
        cnt = np.bincount(s["idx"], minlength=512)
        assert (cnt > 0).all(), f"seed {seed}"
