"""AOT layer: signatures, registry coverage, HLO text round-trip via the
same xla_client conversion path the artifacts use."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, methods, model
from compile.configs import BASE, LM, with_method


def test_registry_covers_every_paper_experiment():
    arts = aot.registry()
    names = set(arts)
    # Table 2: every GLUE method on both scales, both head types
    for size in ("base", "large"):
        for m in aot.GLUE_METHODS:
            for c in (1, 2):
                assert f"glue_{size}_{m}_c{c}_cls_train" in names
    # Tables 6/7 ablations
    for m in ("local", "nonuniform", "fastfood"):
        assert f"glue_large_{m}_c2_cls_train" in names
    # Table 3/4/12 LM methods + rank-64 LoRA
    for m in aot.LM_METHODS:
        assert f"lm_{m}_lm_train" in names
    assert "lm_lora_r64_lm_train" in names
    # Table 5 vision incl. LP/FF
    for size in ("base", "large"):
        assert f"vit_{size}_none_cls_train" in names
        assert f"vit_{size}_full_full_cls_train" in names
    # Figures 3/4 sweeps + pretraining + e2e
    assert any(n.startswith("fig3_") for n in names)
    assert any(n.startswith("fig4_") for n in names)
    for size in ("base", "large", "lm", "e2e"):
        assert f"pretrain_{size}_pretrain_lm" in names
    assert "e2e_uni_lm_train" in names


@pytest.mark.parametrize("kind", list(aot.BUILDERS))
def test_signature_matches_builder_arity(kind):
    cfg = with_method(BASE if kind.startswith(("cls", "full")) else LM, "uni")
    if kind in ("pretrain_lm", "full_cls_train"):
        cfg = with_method(cfg, "none", n_classes=0 if kind == "pretrain_lm" else 2)
    sig, outs = aot.signature(cfg, kind)
    args = [
        jnp.zeros(s, jnp.int32 if dt == "i32" else jnp.float32)
        for _, dt, s in sig
    ]
    fn = aot.BUILDERS[kind](cfg)
    res = fn(*args)
    assert len(res) == len(outs)


def test_lower_one_writes_hlo_and_meta(tmp_path):
    cfg = with_method(BASE, "uni", n_classes=2)
    meta = aot.lower_one("tiny_test", cfg, "cls_eval", str(tmp_path))
    hlo = (tmp_path / "tiny_test.hlo.txt").read_text()
    assert "ENTRY" in hlo
    assert meta["d"] == cfg.d
    assert meta["D"] == cfg.d_full
    # input count in meta matches the HLO entry parameter count
    entry = hlo[hlo.index("ENTRY"):]
    n_params = entry.count("parameter(")
    assert n_params == len(meta["inputs"])


def test_manifest_exists_and_is_consistent():
    man = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
    if not os.path.exists(man):
        pytest.skip("artifacts not built")
    with open(man) as f:
        manifest = json.load(f)
    assert len(manifest) >= 100
    for name, meta in list(manifest.items())[:10]:
        assert meta["name"] == name
        assert os.path.exists(os.path.join(os.path.dirname(man), meta["hlo"]))
        total = sum(int(np.prod(s["shape"])) for s in meta["theta_segments"])
        assert meta["d"] == max(total, 1)
