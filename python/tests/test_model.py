"""L2 model: shapes, masking, loss semantics, and end-to-end
trainability of the jitted step functions for every method."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, methods, model, optim, unirng as rng
from compile.configs import BASE, LM, with_method


def make_inputs(cfg, seed=0):
    th = jnp.asarray(methods.init_theta(cfg, seed))
    stats = [jnp.asarray(v) for _, v in sorted(
        methods.gen_statics(cfg, seed).items(),
        key=lambda kv: [n for n, _, _ in methods.statics_spec(cfg)].index(kv[0]),
    )] if methods.statics_spec(cfg) else []
    P = model.base_param_count(cfg)
    w0 = jnp.asarray(np.concatenate([
        methods.init_array(init, shape, rng.child_seed(seed, 500 + i)).ravel()
        for i, (name, shape, init) in enumerate(model.base_segments(cfg))
    ]))
    assert w0.shape == (P,)
    toks = jnp.asarray(
        rng.indices(seed + 1, cfg.batch * cfg.seq, cfg.vocab).reshape(cfg.batch, cfg.seq),
        jnp.int32)
    return th, stats, w0, toks


def test_forward_shape_and_finite():
    cfg = with_method(BASE, "uni")
    th, stats, w0, toks = make_inputs(cfg)
    sd = dict(zip([n for n, _, _ in methods.statics_spec(cfg)], stats))
    h = model.forward(cfg, w0, th, sd, toks)
    assert h.shape == (cfg.batch, cfg.seq, cfg.hidden)
    assert bool(jnp.isfinite(h).all())


def test_cls_output_mask_effect():
    """Padding tokens beyond attn_len must not change the pooled output."""
    cfg = with_method(BASE, "uni")
    th, stats, w0, toks = make_inputs(cfg)
    sd = dict(zip([n for n, _, _ in methods.statics_spec(cfg)], stats))
    head = jnp.asarray(rng.normals(9, model.head_param_count(cfg)))
    alen = jnp.full((cfg.batch,), 10, jnp.int32)
    out1 = model.cls_output(cfg, w0, th, sd, head, toks, alen)
    toks2 = toks.at[:, 20:].set(0)  # change only padding region
    out2 = model.cls_output(cfg, w0, th, sd, head, toks2, alen)
    # causal attention means tokens after position t cannot affect
    # positions <= t; pooling masks them, so outputs are identical
    np.testing.assert_allclose(out1, out2, atol=1e-5)


def test_lm_loss_masking():
    cfg = with_method(LM, "uni")
    logits = jnp.asarray(rng.normals(3, 2 * 4 * cfg.vocab).reshape(2, 4, cfg.vocab))
    labels = jnp.asarray([[1, 2, -1, -1], [3, -1, -1, -1]], jnp.int32)
    l1 = model.lm_loss(cfg, logits, labels)
    # changing masked labels must not change loss
    labels2 = jnp.asarray([[1, 2, 5, 6], [3, 7, 8, 9]], jnp.int32)
    labels2 = jnp.where(labels >= 0, labels2, -1)
    l2 = model.lm_loss(cfg, logits, labels2)
    assert l1.shape == ()
    np.testing.assert_allclose(l1, l2)


def test_regression_head_mse():
    cfg = with_method(BASE, "uni", n_classes=1)
    logits = jnp.asarray([[1.0], [2.0]])
    labels = jnp.asarray([1.5, 1.5])
    np.testing.assert_allclose(model.cls_loss(cfg, logits, labels), 0.25)


def test_adamw_matches_numpy_oracle():
    n = 64
    th = rng.normals(1, n)
    g = rng.normals(2, n)
    m = np.zeros(n, np.float32)
    v = np.zeros(n, np.float32)
    t2, m2, v2 = optim.adamw(
        jnp.asarray(th), jnp.asarray(g), jnp.asarray(m), jnp.asarray(v),
        jnp.asarray(1, jnp.int32), jnp.float32(1e-3), jnp.float32(0.01))
    em = 0.1 * g
    ev = 0.001 * g * g
    mh = em / (1 - 0.9)
    vh = ev / (1 - 0.999)
    want = th - 1e-3 * (mh / (np.sqrt(vh) + 1e-8) + 0.01 * th)
    np.testing.assert_allclose(t2, want, rtol=1e-5)
    np.testing.assert_allclose(m2, em, rtol=1e-5)
    np.testing.assert_allclose(v2, ev, rtol=1e-5)


@pytest.mark.parametrize("meth", ["uni", "lora", "vera", "vb", "lora_xs",
                                  "fourierft", "fastfood", "tied"])
def test_cls_train_step_learns(meth):
    """A few steps of the *actual artifact function* reduce the loss on a
    linearly separable toy batch — per method."""
    cfg = with_method(BASE, meth, n_classes=2)
    th, stats, w0, toks = make_inputs(cfg, seed=3)
    step_fn = jax.jit(aot.make_cls_train(cfg))
    dh = model.head_param_count(cfg)
    head = jnp.zeros((dh,))
    m = jnp.zeros_like(th); v = jnp.zeros_like(th)
    hm = jnp.zeros_like(head); hv = jnp.zeros_like(head)
    # labels correlated with first token id parity -> learnable
    labels = jnp.asarray(np.asarray(toks[:, 0]) % 2, jnp.int32)
    alen = jnp.full((cfg.batch,), cfg.seq, jnp.int32)
    losses = []
    for i in range(1, 9):
        th, m, v, head, hm, hv, loss = step_fn(
            th, m, v, head, hm, hv, jnp.asarray(i, jnp.int32),
            jnp.float32(5e-3), jnp.float32(5e-2), jnp.float32(0.0),
            w0, toks, alen, labels, *stats)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_lm_train_step_learns():
    cfg = with_method(LM, "uni")
    th, stats, w0, toks = make_inputs(cfg, seed=5)
    step_fn = jax.jit(aot.make_lm_train(cfg))
    m = jnp.zeros_like(th); v = jnp.zeros_like(th)
    labels = jnp.concatenate([toks[:, 1:], -jnp.ones((cfg.batch, 1), jnp.int32)], 1)
    losses = []
    for i in range(1, 7):
        th, m, v, loss = step_fn(
            th, m, v, jnp.asarray(i, jnp.int32), jnp.float32(1e-2),
            jnp.float32(0.0), w0, toks, labels, *stats)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses
