"""L1 correctness: every Pallas kernel against its pure-jnp oracle,
with hypothesis sweeps over shapes/d/seeds, plus gradient checks through
the custom VJPs (the training graphs differentiate through these)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import unirng as rng
from compile.kernels import fastfood, ref, unilora


def make_idx_nrm(seed, n, d):
    idx = rng.indices(seed, n, d)
    cnt = np.bincount(idx, minlength=d)
    nrm = (1.0 / np.sqrt(np.maximum(cnt, 1)))[idx].astype(np.float32)
    return jnp.asarray(idx, jnp.int32), jnp.asarray(nrm)


@given(st.integers(0, 1000), st.integers(2, 512), st.integers(8, 4096))
@settings(max_examples=30, deadline=None)
def test_project_matches_ref(seed, d, big_d):
    th = jnp.asarray(rng.normals(seed, d))
    idx, nrm = make_idx_nrm(seed + 1, big_d, d)
    got = unilora.project(th, idx, nrm)
    want = ref.project_ref(th, idx, nrm)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@given(st.integers(0, 1000), st.sampled_from([1, 2, 4, 8]),
       st.sampled_from([8, 16, 64]), st.integers(1, 33))
@settings(max_examples=25, deadline=None)
def test_apply_matches_ref(seed, r, h, m_rows):
    d = 32
    th = jnp.asarray(rng.normals(seed, d))
    idx, nrm = make_idx_nrm(seed + 1, 2 * h * r, d)
    ia, na, ib, nb = idx[: h * r], nrm[: h * r], idx[h * r:], nrm[h * r:]
    x = jnp.asarray(rng.normals(seed + 2, m_rows * h).reshape(m_rows, h))
    w = jnp.asarray(rng.normals(seed + 3, h * h).reshape(h, h))
    got = unilora.apply(x, w, th, ia, na, ib, nb, r, 2.0)
    want = ref.unilora_matmul_ref(x, w, th, ia, na, ib, nb, r, 2.0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_apply_grads_match_ref():
    d, h, r, m_rows = 64, 16, 4, 8
    th = jnp.asarray(rng.normals(1, d))
    idx, nrm = make_idx_nrm(2, 2 * h * r, d)
    ia, na, ib, nb = idx[: h * r], nrm[: h * r], idx[h * r:], nrm[h * r:]
    x = jnp.asarray(rng.normals(3, m_rows * h).reshape(m_rows, h))
    w = jnp.asarray(rng.normals(4, h * h).reshape(h, h))

    def lk(t, xx):
        return jnp.sum(unilora.apply(xx, w, t, ia, na, ib, nb, r, 2.0) ** 2)

    def lr(t, xx):
        return jnp.sum(ref.unilora_matmul_ref(xx, w, t, ia, na, ib, nb, r, 2.0) ** 2)

    gk = jax.grad(lk, argnums=(0, 1))(th, x)
    gr = jax.grad(lr, argnums=(0, 1))(th, x)
    np.testing.assert_allclose(gk[0], gr[0], rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gk[1], gr[1], rtol=1e-3, atol=1e-3)


def test_project_t_is_transpose():
    """<P x, y> == <x, P^T y> — project_t really is the adjoint."""
    d, D = 32, 256
    idx, nrm = make_idx_nrm(11, D, d)
    x = jnp.asarray(rng.normals(12, d))
    y = jnp.asarray(rng.normals(13, D))
    lhs = jnp.dot(unilora.project(x, idx, nrm), y)
    rhs = jnp.dot(x, unilora.project_t(y, idx, nrm, d))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5)


@given(st.integers(0, 500), st.sampled_from([2, 8, 64, 256]))
@settings(max_examples=20, deadline=None)
def test_fwht_involution_and_isometry(seed, n):
    v = jnp.asarray(rng.normals(seed, n))
    h = fastfood.fwht(v)
    np.testing.assert_allclose(fastfood.fwht(h), v, atol=1e-4)
    np.testing.assert_allclose(jnp.linalg.norm(h), jnp.linalg.norm(v), rtol=1e-5)


@given(st.integers(0, 500), st.sampled_from([16, 64, 128]))
@settings(max_examples=15, deadline=None)
def test_fastfood_block_matches_ref(seed, d):
    th = jnp.asarray(rng.normals(seed, d))
    sb = jnp.asarray(rng.signs(seed + 1, d))
    g = jnp.asarray(rng.normals(seed + 2, d))
    pm = jnp.asarray(rng.permutation(seed + 3, d), jnp.int32)
    ss = jnp.asarray(rng.signs(seed + 4, d))
    got = fastfood.fastfood_block(th, sb, g, pm, ss)
    want = ref.fastfood_block_ref(th, sb, g, pm, ss)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_fastfood_grad_matches_ref():
    d = 64
    th = jnp.asarray(rng.normals(1, d))
    sb = jnp.asarray(rng.signs(2, d))
    g = jnp.asarray(rng.normals(3, d))
    pm = jnp.asarray(rng.permutation(4, d), jnp.int32)
    ss = jnp.asarray(rng.signs(5, d))

    g1 = jax.grad(lambda t: jnp.sum(fastfood.fastfood_block(t, sb, g, pm, ss) ** 3))(th)
    g2 = jax.grad(lambda t: jnp.sum(ref.fastfood_block_ref(t, sb, g, pm, ss) ** 3))(th)
    np.testing.assert_allclose(g1, g2, rtol=1e-3, atol=1e-4)


def test_fastfood_project_truncation():
    d, out_len = 32, 70  # forces nb = 3 blocks
    nb = 3
    th = jnp.asarray(rng.normals(1, d))
    sb = jnp.asarray(rng.signs(2, nb * d).reshape(nb, d))
    g = jnp.asarray(rng.normals(3, nb * d).reshape(nb, d))
    pm = jnp.asarray(
        np.stack([rng.permutation(4 + i, d) for i in range(nb)]), jnp.int32
    )
    ss = jnp.asarray(rng.signs(7, nb * d).reshape(nb, d))
    got = fastfood.fastfood_project(th, sb, g, pm, ss, out_len)
    want = ref.fastfood_project_ref(th, sb, g, pm, ss, out_len)
    assert got.shape == (out_len,)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_project_dtype_preserved():
    d, D = 16, 64
    idx, nrm = make_idx_nrm(3, D, d)
    th = jnp.asarray(rng.normals(1, d))
    assert unilora.project(th, idx, nrm).dtype == jnp.float32
