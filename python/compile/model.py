"""L2: the MiniLM transformer backbone with PEFT-adapted q/v projections.

A single pre-LN causal transformer serves every experiment:
  * classification / regression head (GLUE-like, vision)  — mean-pool
  * LM head (math reasoning, instruction tuning, pretraining)

Base weights are a single flat f32 vector `w0` (runtime input, frozen
during fine-tuning); `base_segments` records the layout, which the Rust
coordinator reads from the artifact meta to initialize / checkpoint the
backbone. The adapted matmuls (q and v, paper §4.1) route through
methods.apply, i.e. through the L1 Pallas kernels.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from . import methods
from .configs import ModelCfg


def base_segments(cfg: ModelCfg):
    """Flat layout of the frozen backbone: list of (name, shape, init)."""
    h, f, V, T = cfg.hidden, cfg.ffn, cfg.vocab, cfg.seq
    segs = [
        ("tok_emb", (V, h), "normal:0.02"),
        ("pos_emb", (T, h), "normal:0.02"),
    ]
    for l in range(cfg.layers):
        segs += [
            (f"ln1_g{l}", (h,), "ones"),
            (f"ln1_b{l}", (h,), "zeros"),
            (f"wq{l}", (h, h), "normal:0.02"),
            (f"wk{l}", (h, h), "normal:0.02"),
            (f"wv{l}", (h, h), "normal:0.02"),
            (f"wo{l}", (h, h), "normal:0.02"),
            (f"ln2_g{l}", (h,), "ones"),
            (f"ln2_b{l}", (h,), "zeros"),
            (f"w1{l}", (h, f), "normal:0.02"),
            (f"w2{l}", (f, h), "normal:0.02"),
        ]
    segs += [("lnf_g", (h,), "ones"), ("lnf_b", (h,), "zeros")]
    segs += [("lm_head", (h, V), "normal:0.02")]
    return segs


def base_param_count(cfg: ModelCfg) -> int:
    return sum(int(np.prod(s)) for _, s, _ in base_segments(cfg))


def head_param_count(cfg: ModelCfg) -> int:
    c = max(cfg.n_classes, 1)
    return cfg.hidden * c + c


def unflatten_base(cfg: ModelCfg, w0):
    return methods.unflatten(w0, base_segments(cfg))


def _layer_norm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * g + b


def _attention(cfg: ModelCfg, q, k, v):
    """Causal multi-head attention. q/k/v: [B, T, h]."""
    B, T, h = q.shape
    nh, hd = cfg.heads, cfg.head_dim

    def split(t):
        return t.reshape(B, T, nh, hd).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    att = jnp.einsum("bnid,bnjd->bnij", qh, kh) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((T, T), bool))
    att = jnp.where(mask[None, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bnij,bnjd->bnid", att, vh)
    return out.transpose(0, 2, 1, 3).reshape(B, T, h)


def forward(cfg: ModelCfg, w0, theta, statics, tokens):
    """Backbone forward. tokens [B, T] i32 -> hidden states [B, T, h]."""
    p = unflatten_base(cfg, w0)
    tm = methods.unflatten(theta, methods.theta_segments(cfg)) \
        if methods.theta_segments(cfg) else {}
    T = tokens.shape[1]
    x = p["tok_emb"][tokens] + p["pos_emb"][None, :T]
    for l in range(cfg.layers):
        x2 = _layer_norm(x, p[f"ln1_g{l}"], p[f"ln1_b{l}"])
        q = methods.apply(cfg, tm, statics, 2 * l, x2, p[f"wq{l}"])
        k = x2 @ p[f"wk{l}"]
        v = methods.apply(cfg, tm, statics, 2 * l + 1, x2, p[f"wv{l}"])
        x = x + _attention(cfg, q, k, v) @ p[f"wo{l}"]
        x2 = _layer_norm(x, p[f"ln2_g{l}"], p[f"ln2_b{l}"])
        x = x + jax.nn.gelu(x2 @ p[f"w1{l}"]) @ p[f"w2{l}"]
    return _layer_norm(x, p["lnf_g"], p["lnf_b"])


def cls_output(cfg: ModelCfg, w0, theta, statics, head, tokens, attn_len):
    """Mean-pooled classification/regression output [B, C].

    attn_len [B] i32: number of real (non-pad) tokens per example."""
    hidden = forward(cfg, w0, theta, statics, tokens)
    T = tokens.shape[1]
    pos = jnp.arange(T)[None, :]
    m = (pos < attn_len[:, None]).astype(hidden.dtype)
    pooled = (hidden * m[..., None]).sum(1) / jnp.maximum(m.sum(1, keepdims=True), 1.0)
    c = max(cfg.n_classes, 1)
    wh = head[: cfg.hidden * c].reshape(cfg.hidden, c)
    bh = head[cfg.hidden * c:]
    return pooled @ wh + bh


def lm_logits(cfg: ModelCfg, w0, theta, statics, tokens):
    """Next-token logits [B, T, V]."""
    hidden = forward(cfg, w0, theta, statics, tokens)
    p = unflatten_base(cfg, w0)
    return hidden @ p["lm_head"]


def cls_loss(cfg: ModelCfg, logits, labels):
    """CE for C>=2; MSE (labels f32) for regression (C == 1)."""
    if cfg.n_classes == 1:
        return jnp.mean((logits[:, 0] - labels) ** 2)
    lp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(lp, labels[:, None], axis=1))


def lm_loss(cfg: ModelCfg, logits, labels):
    """Next-token CE; positions with label < 0 are masked (prompt/pad)."""
    V = logits.shape[-1]
    lp = jax.nn.log_softmax(logits, axis=-1)
    safe = jnp.maximum(labels, 0)
    nll = -jnp.take_along_axis(lp, safe[..., None], axis=-1)[..., 0]
    m = (labels >= 0).astype(nll.dtype)
    return (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
