"""Pallas kernel for the Fastfood baseline (Uni-LoRA (Fastfood), Table 6).

Fastfood projects theta through S.H.G_hat.Pi.H.B — O(D log d) against
Uni-LoRA's O(D). The orthonormal FWHT is a log2(d)-stage butterfly inside
one Pallas block (on TPU a VPU-friendly in-VMEM schedule; here
interpret=True). A custom VJP makes the block differentiable: every
factor is orthogonal-or-diagonal, so the backward pass is the transpose
chain B.H.Pi^T.G_hat.H.S — same structure, same kernel shapes.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .unilora import INTERPRET, _int_zero


def _fwht_body(v, d):
    """Orthonormal FWHT of a [d] vector (jnp ops, used inside kernels)."""
    h = 1
    y = v
    while h < d:
        y = y.reshape(d // (2 * h), 2, h)
        a = y[:, 0, :]
        b = y[:, 1, :]
        y = jnp.stack([a + b, a - b], axis=1)
        h *= 2
    return y.reshape(d) / jnp.sqrt(jnp.asarray(d, v.dtype))


def fwht(x):
    """FWHT of a [d] vector as a Pallas kernel (d a power of two).
    Self-inverse and self-adjoint, so it is its own VJP."""
    d = x.shape[-1]
    assert d & (d - 1) == 0, "FWHT length must be a power of two"

    def kernel(x_ref, o_ref):
        o_ref[...] = _fwht_body(x_ref[...], d)

    call = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )

    @jax.custom_vjp
    def f(v):
        return call(v)

    f.defvjp(lambda v: (call(v), None), lambda _, g: (call(g),))
    return f(x)


def _block_raw(theta, sgn_b, gauss, perm, sgn_s):
    d = theta.shape[0]

    def kernel(th_ref, sb_ref, g_ref, p_ref, ss_ref, o_ref):
        th = th_ref[...]
        g = g_ref[...]
        g_hat = g * jnp.sqrt(jnp.asarray(d, th.dtype)) / jnp.sqrt(jnp.sum(g * g))
        v = _fwht_body(th * sb_ref[...], d)
        v = v[p_ref[...]] * g_hat
        v = _fwht_body(v, d)
        o_ref[...] = v * ss_ref[...]

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((d,), theta.dtype),
        interpret=INTERPRET,
    )(theta, sgn_b, gauss, perm, sgn_s)


def _block_bwd_raw(g_out, sgn_b, gauss, perm, sgn_s):
    """Transpose chain: gtheta = B.H.Pi^T(G_hat.H(S.g))."""
    d = g_out.shape[0]

    def kernel(g_ref, sb_ref, gg_ref, p_ref, ss_ref, o_ref):
        gg = gg_ref[...]
        g_hat = gg * jnp.sqrt(jnp.asarray(d, gg.dtype)) / jnp.sqrt(jnp.sum(gg * gg))
        v = _fwht_body(g_ref[...] * ss_ref[...], d)
        v = v * g_hat
        v = jnp.zeros((d,), v.dtype).at[p_ref[...]].add(v)  # Pi^T scatter
        v = _fwht_body(v, d)
        o_ref[...] = v * sb_ref[...]

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((d,), g_out.dtype),
        interpret=INTERPRET,
    )(g_out, sgn_b, gauss, perm, sgn_s)


@jax.custom_vjp
def fastfood_block(theta, sgn_b, gauss, perm, sgn_s):
    """One Fastfood block S*H(G_hat*Pi(H(B*theta))): theta [d] -> [d]."""
    return _block_raw(theta, sgn_b, gauss, perm, sgn_s)


def _ff_fwd(theta, sgn_b, gauss, perm, sgn_s):
    return _block_raw(theta, sgn_b, gauss, perm, sgn_s), (sgn_b, gauss, perm, sgn_s)


def _ff_bwd(res, g):
    sgn_b, gauss, perm, sgn_s = res
    gt = _block_bwd_raw(g, sgn_b, gauss, perm, sgn_s)
    # frozen statics: zero cotangents (correct enough for frozen inputs;
    # they are never trained anywhere in this system)
    return gt, jnp.zeros_like(sgn_b), jnp.zeros_like(gauss), _int_zero(perm), \
        jnp.zeros_like(sgn_s)


fastfood_block.defvjp(_ff_fwd, _ff_bwd)


def fastfood_project(theta, sgn_b, gauss, perm, sgn_s, out_len):
    """Full projection R^d -> R^out_len (nb blocks, concat + truncate).
    Statics have leading dim nb."""
    nb = sgn_b.shape[0]
    outs = [
        fastfood_block(theta, sgn_b[i], gauss[i], perm[i], sgn_s[i])
        for i in range(nb)
    ]
    return jnp.concatenate(outs)[:out_len]
