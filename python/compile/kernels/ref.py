"""Pure-jnp oracles for the Pallas kernels.

These are the ground-truth semantics: every Pallas kernel in this package
is pytest-checked (with hypothesis shape/dtype sweeps) against the
functions here, and the L2 model can be built against either
implementation (`use_pallas` flag) — both lower into the same HLO
artifact format.
"""
from __future__ import annotations

import jax.numpy as jnp


def project_ref(theta, idx, nrm):
    """The Uni-LoRA projection theta_D = P theta_d, computed as the O(D)
    gather theta_d[idx] * nrm (P is never materialized)."""
    return theta[idx] * nrm


def gather_ab_ref(theta, idx, nrm, shape):
    """Reconstruct one LoRA factor (A or B) from the shared vector."""
    return (theta[idx] * nrm).reshape(shape)


def unilora_matmul_ref(x, w0, theta, idx_a, nrm_a, idx_b, nrm_b, r, scale):
    """Adapted matmul y = x @ W0 + scale * (x @ A) @ B with A, B gathered
    on the fly from theta (paper Alg. 1 forward). Shapes:
      x [M, n_in], w0 [n_in, n_out], A [n_in, r], B [r, n_out].
    """
    n_in = x.shape[-1]
    n_out = w0.shape[-1]
    a = gather_ab_ref(theta, idx_a, nrm_a, (n_in, r))
    b = gather_ab_ref(theta, idx_b, nrm_b, (r, n_out))
    return x @ w0 + scale * ((x @ a) @ b)


def fwht_ref(x):
    """Orthonormal fast Walsh-Hadamard transform along the last axis
    (power-of-two length). Self-inverse: fwht(fwht(x)) == x."""
    n = x.shape[-1]
    assert n & (n - 1) == 0, "FWHT length must be a power of two"
    shape = x.shape
    h = 1
    y = x.reshape(-1, n)
    while h < n:
        y = y.reshape(-1, n // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    return (y.reshape(shape) / jnp.sqrt(jnp.asarray(n, x.dtype))).astype(x.dtype)


def fastfood_block_ref(theta, sgn_b, gauss, perm, sgn_s):
    """One Fastfood block: v = S * H(G_hat * Pi(H(B * theta))).

    theta: [d] (d a power of two). Returns [d]. G is normalized so the
    block is (approximately) isometric: G_hat = G * sqrt(d) / ||G||.
    """
    d = theta.shape[0]
    g_hat = gauss * jnp.sqrt(jnp.asarray(d, theta.dtype)) / jnp.linalg.norm(gauss)
    v = fwht_ref(theta * sgn_b)
    v = v[perm] * g_hat
    v = fwht_ref(v)
    return v * sgn_s


def fastfood_project_ref(theta, sgn_b, gauss, perm, sgn_s, out_len):
    """Full Fastfood projection R^d -> R^out_len: nb = ceil(out_len/d)
    independent blocks, concatenated and truncated. Statics have leading
    dim nb."""
    nb = sgn_b.shape[0]
    outs = [
        fastfood_block_ref(theta, sgn_b[i], gauss[i], perm[i], sgn_s[i])
        for i in range(nb)
    ]
    return jnp.concatenate(outs)[:out_len]
