"""Pallas kernels for the Uni-LoRA hot path, with custom VJPs so the L2
training graphs differentiate *through* the kernels (pallas_call has no
built-in reverse rule).

Kernels:
  * `project`    — theta_D = P theta_d as an O(D) VMEM gather.
  * `project_t`  — the transpose P^T g (O(D) scatter-add); this is the
    backward hot path: because P^T P = I (Theorem 1), the gradient w.r.t.
    theta_d is exactly the scatter of the LoRA-space gradient.
  * `apply`      — fused adapted matmul y = x@W0 + scale*(x@A)@B with
    A, B reconstructed in-kernel; DeltaW never materializes.

TPU thinking (DESIGN.md §Hardware-Adaptation): theta_d pins in VMEM for
the whole grid; idx/nrm tiles share the BlockSpec of the A/B tiles they
produce; the matmuls target the MXU. On this CPU image we lower with
interpret=True (Mosaic custom-calls are not runnable on CPU PJRT) and
size grids so one block covers each small operand, which lowers the
kernels to straight-line HLO.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot execute Mosaic custom-calls.


def _int_zero(x):
    """float0 cotangent for integer inputs (required by custom_vjp)."""
    return np.zeros(x.shape, dtype=jax.dtypes.float0)


# --------------------------------------------------------------------------
# projection kernels


def _project_kernel(th_ref, idx_ref, nrm_ref, o_ref):
    th = th_ref[...]
    o_ref[...] = th[idx_ref[...]] * nrm_ref[...]


def _project_raw(theta, idx, nrm):
    return pl.pallas_call(
        _project_kernel,
        out_shape=jax.ShapeDtypeStruct(idx.shape, theta.dtype),
        interpret=INTERPRET,
    )(theta, idx, nrm)


def project_t(g, idx, nrm, d):
    """Transpose projection P^T g: out[j] = sum_{i: idx[i]=j} g[i]*nrm[i].

    O(D) scatter-add — the gradient route back into theta_d."""

    def kernel(g_ref, idx_ref, nrm_ref, o_ref):
        gv = g_ref[...] * nrm_ref[...]
        o_ref[...] = jnp.zeros((d,), gv.dtype).at[idx_ref[...]].add(gv)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((d,), g.dtype),
        interpret=INTERPRET,
    )(g, idx, nrm)


@jax.custom_vjp
def project(theta, idx, nrm):
    """theta_D = P theta_d (O(D) gather; differentiable w.r.t. theta)."""
    return _project_raw(theta, idx, nrm)


def _project_fwd(theta, idx, nrm):
    return _project_raw(theta, idx, nrm), (theta, idx, nrm)


def _project_bwd(res, g):
    theta, idx, nrm = res
    gt = project_t(g, idx, nrm, theta.shape[0])
    gnrm = g * theta[idx]
    return gt, _int_zero(idx), gnrm


project.defvjp(_project_fwd, _project_bwd)


# --------------------------------------------------------------------------
# fused adapted matmul


def _apply_raw(r, scale, x, w0, theta, idx_a, nrm_a, idx_b, nrm_b):
    m_rows, n_in = x.shape
    n_out = w0.shape[1]

    def kernel(x_ref, w_ref, th_ref, ia_ref, na_ref, ib_ref, nb_ref, o_ref):
        th = th_ref[...]
        a = (th[ia_ref[...]] * na_ref[...]).reshape(n_in, r)
        b = (th[ib_ref[...]] * nb_ref[...]).reshape(r, n_out)
        xv = x_ref[...]
        o_ref[...] = xv @ w_ref[...] + scale * ((xv @ a) @ b)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m_rows, n_out), x.dtype),
        interpret=INTERPRET,
    )(x, w0, theta, idx_a, nrm_a, idx_b, nrm_b)


def _apply_bwd_kernel(r, scale, d, x, w0, theta, idx_a, nrm_a, idx_b, nrm_b, g):
    """Fused backward: one Pallas kernel produces (gx, gtheta).

    A, B are *regenerated* from theta (never stored — the memory-
    efficiency point), then:
      gx     = g @ W0^T + scale * (g @ B^T) @ A^T
      gA     = scale * x^T (g B^T),  gB = scale * (x A)^T g
      gtheta = P_a^T vec(gA) + P_b^T vec(gB)   (scatter-add)
    """
    m_rows, n_in = x.shape
    n_out = w0.shape[1]

    def kernel(x_ref, w_ref, th_ref, ia_ref, na_ref, ib_ref, nb_ref, g_ref,
               gx_ref, gth_ref):
        th = th_ref[...]
        ia, na = ia_ref[...], na_ref[...]
        ib, nb = ib_ref[...], nb_ref[...]
        a = (th[ia] * na).reshape(n_in, r)
        b = (th[ib] * nb).reshape(r, n_out)
        xv, gv = x_ref[...], g_ref[...]
        gbt = gv @ b.T                        # [M, r]
        gx_ref[...] = gv @ w_ref[...].T + scale * (gbt @ a.T)
        ga = scale * (xv.T @ gbt)             # [n_in, r]
        gb = scale * ((xv @ a).T @ gv)        # [r, n_out]
        gth = jnp.zeros((d,), th.dtype)
        gth = gth.at[ia].add(ga.reshape(-1) * na)
        gth = gth.at[ib].add(gb.reshape(-1) * nb)
        gth_ref[...] = gth

    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((m_rows, n_in), x.dtype),
            jax.ShapeDtypeStruct((d,), theta.dtype),
        ),
        interpret=INTERPRET,
    )(x, w0, theta, idx_a, nrm_a, idx_b, nrm_b, g)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def apply_core(r, scale, x, w0, theta, idx_a, nrm_a, idx_b, nrm_b):
    return _apply_raw(r, scale, x, w0, theta, idx_a, nrm_a, idx_b, nrm_b)


def _apply_fwd(r, scale, x, w0, theta, idx_a, nrm_a, idx_b, nrm_b):
    y = _apply_raw(r, scale, x, w0, theta, idx_a, nrm_a, idx_b, nrm_b)
    return y, (x, w0, theta, idx_a, nrm_a, idx_b, nrm_b)


def _apply_bwd(r, scale, res, g):
    x, w0, theta, idx_a, nrm_a, idx_b, nrm_b = res
    d = theta.shape[0]
    gx, gth = _apply_bwd_kernel(r, scale, d, x, w0, theta,
                                idx_a, nrm_a, idx_b, nrm_b, g)
    # w0 is frozen in every adapter graph; the x^T g term is still the
    # mathematically correct cotangent and is DCE'd by XLA when unused.
    gw0 = x.T @ g
    zf = jnp.zeros_like(nrm_a), jnp.zeros_like(nrm_b)
    return (gx, gw0, gth, _int_zero(idx_a), zf[0], _int_zero(idx_b), zf[1])


apply_core.defvjp(_apply_fwd, _apply_bwd)


def apply(x, w0, theta, idx_a, nrm_a, idx_b, nrm_b, r, scale):
    """Fused adapted matmul: y = x @ W0 + scale * (x @ A) @ B.

    x [M, n_in], w0 [n_in, n_out], idx_a/nrm_a [n_in*r], idx_b/nrm_b
    [r*n_out]. A and B are gathered from theta inside the kernel, in both
    the forward and backward passes.
    """
    return apply_core(int(r), float(scale), x, w0, theta,
                      idx_a, nrm_a, idx_b, nrm_b)
