"""AdamW, written against flat f32 vectors so optimizer state moves
through the artifact boundary as plain arrays (the Rust coordinator owns
them as device buffers between steps)."""
from __future__ import annotations

import jax.numpy as jnp

B1, B2, EPS = 0.9, 0.999, 1e-8


def adamw(theta, grad, m, v, step, lr, wd):
    """One AdamW update. `step` is the 1-based i32 step counter (scalar),
    lr/wd f32 scalars. Returns (theta', m', v')."""
    t = step.astype(jnp.float32)
    m2 = B1 * m + (1.0 - B1) * grad
    v2 = B2 * v + (1.0 - B2) * grad * grad
    mhat = m2 / (1.0 - B1**t)
    vhat = v2 / (1.0 - B2**t)
    upd = mhat / (jnp.sqrt(vhat) + EPS) + wd * theta
    return theta - lr * upd, m2, v2
