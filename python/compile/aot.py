"""AOT: lower every experiment's train/eval graphs to HLO text + a
manifest the Rust runtime consumes. Python runs ONCE (`make artifacts`);
after that the Rust binary is self-contained.

Interchange is HLO *text*: jax >= 0.5 serializes HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version behind the
published `xla` crate) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifact kinds and positional signatures (all f32 unless noted):
  cls_train   (theta[d], m[d], v[d], head[dh], hm[dh], hv[dh],
               step[] i32, lr_t[], lr_h[], wd[], w0[P],
               tokens[B,T] i32, attn_len[B] i32, labels[B] i32|f32,
               *statics) -> (theta', m', v', head', hm', hv', loss)
  cls_eval    (theta[d], head[dh], w0[P], tokens, attn_len, *statics)
              -> (logits[B,C],)
  lm_train    (theta, m, v, step, lr_t, wd, w0, tokens[B,T] i32,
               labels[B,T] i32, *statics) -> (theta', m', v', loss)
  lm_logits   (theta, w0, tokens, *statics) -> (logits[B,T,V],)
  pretrain_lm (w0[P], m[P], v[P], step, lr, wd, tokens, labels)
              -> (w0', m', v', loss)
  full_cls_train (w0, m, v, head, hm, hv, step, lr_t, lr_h, wd,
               tokens, attn_len, labels) -> (w0',m',v',head',hm',hv',loss)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from dataclasses import asdict, replace

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import methods, model, optim
from .configs import BASE, E2E, LARGE, LM, ModelCfg, with_method

F32, I32 = "f32", "i32"


# --------------------------------------------------------------------------
# step builders


def _split_statics(cfg, args):
    names = [n for n, _, _ in methods.statics_spec(cfg)]
    assert len(args) == len(names), (len(args), names)
    return dict(zip(names, args))


def make_cls_train(cfg: ModelCfg):
    def step(theta, m, v, head, hm, hv, step_i, lr_t, lr_h, wd, w0,
             tokens, attn_len, labels, *statics):
        sd = _split_statics(cfg, statics)

        def loss_fn(th, hd):
            logits = model.cls_output(cfg, w0, th, sd, hd, tokens, attn_len)
            return model.cls_loss(cfg, logits, labels)

        loss, (gt, gh) = jax.value_and_grad(loss_fn, argnums=(0, 1))(theta, head)
        th2, m2, v2 = optim.adamw(theta, gt, m, v, step_i, lr_t, wd)
        hd2, hm2, hv2 = optim.adamw(head, gh, hm, hv, step_i, lr_h, jnp.float32(0.0))
        return th2, m2, v2, hd2, hm2, hv2, loss

    return step


def make_cls_eval(cfg: ModelCfg):
    def step(theta, head, w0, tokens, attn_len, *statics):
        sd = _split_statics(cfg, statics)
        return (model.cls_output(cfg, w0, theta, sd, head, tokens, attn_len),)

    return step


def make_lm_train(cfg: ModelCfg):
    def step(theta, m, v, step_i, lr_t, wd, w0, tokens, labels, *statics):
        sd = _split_statics(cfg, statics)

        def loss_fn(th):
            return model.lm_loss(cfg, model.lm_logits(cfg, w0, th, sd, tokens), labels)

        loss, gt = jax.value_and_grad(loss_fn)(theta)
        th2, m2, v2 = optim.adamw(theta, gt, m, v, step_i, lr_t, wd)
        return th2, m2, v2, loss

    return step


def make_lm_logits(cfg: ModelCfg):
    def step(theta, w0, tokens, *statics):
        sd = _split_statics(cfg, statics)
        return (model.lm_logits(cfg, w0, theta, sd, tokens),)

    return step


def make_pretrain_lm(cfg: ModelCfg):
    # method must be "none": the backbone itself is the trainable vector.
    def step(w0, m, v, step_i, lr, wd, tokens, labels):
        def loss_fn(w):
            return model.lm_loss(cfg, model.lm_logits(cfg, w, jnp.zeros((1,)), {}, tokens), labels)

        loss, g = jax.value_and_grad(loss_fn)(w0)
        w2, m2, v2 = optim.adamw(w0, g, m, v, step_i, lr, wd)
        return w2, m2, v2, loss

    return step


def make_full_cls_train(cfg: ModelCfg):
    def step(w0, m, v, head, hm, hv, step_i, lr_t, lr_h, wd,
             tokens, attn_len, labels):
        def loss_fn(w, hd):
            logits = model.cls_output(cfg, w, jnp.zeros((1,)), {}, hd, tokens, attn_len)
            return model.cls_loss(cfg, logits, labels)

        loss, (gw, gh) = jax.value_and_grad(loss_fn, argnums=(0, 1))(w0, head)
        w2, m2, v2 = optim.adamw(w0, gw, m, v, step_i, lr_t, wd)
        hd2, hm2, hv2 = optim.adamw(head, gh, hm, hv, step_i, lr_h, jnp.float32(0.0))
        return w2, m2, v2, hd2, hm2, hv2, loss

    return step


# --------------------------------------------------------------------------
# signatures


def signature(cfg: ModelCfg, kind: str):
    """Positional input signature: list of (name, dtype, shape)."""
    d = methods.d_effective(cfg)
    dh = model.head_param_count(cfg)
    P = model.base_param_count(cfg)
    B, T = cfg.batch, cfg.seq
    lab_dt = F32 if cfg.n_classes == 1 else I32
    stat = [(n, dt, s) for n, dt, s in methods.statics_spec(cfg)]
    if kind == "cls_train":
        sig = [
            ("theta", F32, (d,)), ("m", F32, (d,)), ("v", F32, (d,)),
            ("head", F32, (dh,)), ("hm", F32, (dh,)), ("hv", F32, (dh,)),
            ("step", I32, ()), ("lr_t", F32, ()), ("lr_h", F32, ()), ("wd", F32, ()),
            ("w0", F32, (P,)), ("tokens", I32, (B, T)),
            ("attn_len", I32, (B,)), ("labels", lab_dt, (B,)),
        ] + stat
        outs = ["theta", "m", "v", "head", "hm", "hv", "loss"]
    elif kind == "cls_eval":
        sig = [
            ("theta", F32, (d,)), ("head", F32, (dh,)), ("w0", F32, (P,)),
            ("tokens", I32, (B, T)), ("attn_len", I32, (B,)),
        ] + stat
        outs = ["logits"]
    elif kind == "lm_train":
        sig = [
            ("theta", F32, (d,)), ("m", F32, (d,)), ("v", F32, (d,)),
            ("step", I32, ()), ("lr_t", F32, ()), ("wd", F32, ()),
            ("w0", F32, (P,)), ("tokens", I32, (B, T)), ("labels", I32, (B, T)),
        ] + stat
        outs = ["theta", "m", "v", "loss"]
    elif kind == "lm_logits":
        sig = [
            ("theta", F32, (d,)), ("w0", F32, (P,)), ("tokens", I32, (B, T)),
        ] + stat
        outs = ["logits"]
    elif kind == "pretrain_lm":
        sig = [
            ("w0", F32, (P,)), ("m", F32, (P,)), ("v", F32, (P,)),
            ("step", I32, ()), ("lr", F32, ()), ("wd", F32, ()),
            ("tokens", I32, (B, T)), ("labels", I32, (B, T)),
        ]
        outs = ["w0", "m", "v", "loss"]
    elif kind == "full_cls_train":
        sig = [
            ("w0", F32, (P,)), ("m", F32, (P,)), ("v", F32, (P,)),
            ("head", F32, (dh,)), ("hm", F32, (dh,)), ("hv", F32, (dh,)),
            ("step", I32, ()), ("lr_t", F32, ()), ("lr_h", F32, ()), ("wd", F32, ()),
            ("tokens", I32, (B, T)), ("attn_len", I32, (B,)), ("labels", lab_dt, (B,)),
        ]
        outs = ["w0", "m", "v", "head", "hm", "hv", "loss"]
    else:
        raise ValueError(kind)
    return sig, outs


BUILDERS = {
    "cls_train": make_cls_train,
    "cls_eval": make_cls_eval,
    "lm_train": make_lm_train,
    "lm_logits": make_lm_logits,
    "pretrain_lm": make_pretrain_lm,
    "full_cls_train": make_full_cls_train,
}


# --------------------------------------------------------------------------
# registry of every artifact (DESIGN.md §5 maps these to paper exps)

GLUE_METHODS = ["lora", "vera", "tied", "vb", "lora_xs", "fourierft", "uni"]
ABLATION_METHODS = ["local", "nonuniform", "fastfood"]
LM_METHODS = ["lora", "vera", "vb", "lora_xs", "fourierft", "uni"]


def registry() -> dict[str, tuple[ModelCfg, str]]:
    arts: dict[str, tuple[ModelCfg, str]] = {}

    def add(name, cfg, kinds):
        for k in kinds:
            arts[f"{name}_{k}"] = (cfg, k)

    # Table 2 (GLUE): 2 scales x 7 methods x {cls C=2, reg C=1}
    for size in (BASE, LARGE):
        for meth in GLUE_METHODS:
            for C in (2, 1):
                cfg = with_method(size, meth, n_classes=C)
                add(f"glue_{size.name}_{meth}_c{C}", cfg, ["cls_train", "cls_eval"])

    # Tables 6/7 ablations on the large backbone, classification head
    for meth in ABLATION_METHODS:
        cfg = with_method(LARGE, meth, n_classes=2)
        add(f"glue_large_{meth}_c2", cfg, ["cls_train", "cls_eval"])

    # Figure 3: d-sweep (uni, base backbone)
    for dv in (16, 64, 1024):
        cfg = with_method(BASE, "uni", n_classes=2, d=dv)
        add(f"fig3_base_uni_d{dv}", cfg, ["cls_train", "cls_eval"])

    # Figure 4: rank sweep (uni, base backbone). d = 128 for all points
    # so D/d stays >= 4 even at r = 1 (full-support resampling needs
    # headroom; see paper footnote 1).
    for rv in (1, 2, 4, 8):
        cfg = with_method(BASE, "uni", n_classes=2, rank=rv, d=128)
        add(f"fig4_base_uni_r{rv}", cfg, ["cls_train", "cls_eval"])

    # Tables 3/4/12: LM fine-tuning (math reasoning + instruction tuning)
    for meth in LM_METHODS:
        cfg = with_method(LM, meth)
        add(f"lm_{meth}", cfg, ["lm_train", "lm_logits"])
    add("lm_lora_r64", with_method(LM, "lora", rank=64), ["lm_train", "lm_logits"])
    for dv in (256, 4096):
        add(f"fig3_lm_uni_d{dv}", with_method(LM, "uni", d=dv),
            ["lm_train", "lm_logits"])

    # Table 5 (vision): C=10 heads; LP = none, FF = full fine-tune
    for size in (BASE, LARGE):
        for meth in ("uni", "fourierft", "none"):
            cfg = with_method(size, meth, n_classes=10)
            add(f"vit_{size.name}_{meth}", cfg, ["cls_train", "cls_eval"])
        cfg = with_method(size, "none", n_classes=10)
        add(f"vit_{size.name}_full", cfg, ["full_cls_train"])

    # Pretraining (the in-system "foundation models") + e2e driver
    for size in (BASE, LARGE, LM, E2E):
        cfg = with_method(size, "none", n_classes=0)
        add(f"pretrain_{size.name}", cfg, ["pretrain_lm"])
    add("e2e_uni", with_method(E2E, "uni"), ["lm_train", "lm_logits"])

    return arts


# --------------------------------------------------------------------------
# lowering


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(name: str, cfg: ModelCfg, kind: str, out_dir: str) -> dict:
    sig, outs = signature(cfg, kind)
    args = [
        jax.ShapeDtypeStruct(s, jnp.int32 if dt == I32 else jnp.float32)
        for _, dt, s in sig
    ]
    fn = BUILDERS[kind](cfg)
    t0 = time.time()
    # keep_unused: methods with no trainable adapter ("none"/LP) must keep
    # the positional theta input so every artifact kind shares one
    # signature shape (the Rust runtime validates against the manifest).
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    meta = {
        "name": name,
        "kind": kind,
        "cfg": asdict(cfg),
        "d": methods.d_effective(cfg),
        "D": cfg.d_full,
        "base_params": model.base_param_count(cfg),
        "head_params": model.head_param_count(cfg),
        "theta_segments": [
            {"name": n, "shape": list(s), "init": i}
            for n, s, i in methods.theta_segments(cfg)
        ],
        "base_segments": [
            {"name": n, "shape": list(s), "init": i}
            for n, s, i in model.base_segments(cfg)
        ],
        "statics": [
            {"name": n, "dtype": dt, "shape": list(s)}
            for n, dt, s in methods.statics_spec(cfg)
        ],
        "inputs": [
            {"name": n, "dtype": dt, "shape": list(s)} for n, dt, s in sig
        ],
        "outputs": outs,
        "hlo": f"{name}.hlo.txt",
        "lower_secs": round(time.time() - t0, 2),
    }
    return meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--filter", default="", help="substring filter on artifact names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    arts = registry()
    manifest = {}
    n = 0
    t0 = time.time()
    for name, (cfg, kind) in sorted(arts.items()):
        if args.filter and args.filter not in name:
            continue
        meta = lower_one(name, cfg, kind, args.out)
        manifest[name] = meta
        n += 1
        print(f"[{n}] {name} ({kind}) lowered in {meta['lower_secs']}s", flush=True)
    man_path = os.path.join(args.out, "manifest.json")
    # merge with any existing manifest (supports --filter incremental runs)
    if os.path.exists(man_path) and args.filter:
        with open(man_path) as f:
            old = json.load(f)
        old.update(manifest)
        manifest = old
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {n} artifacts + manifest in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
