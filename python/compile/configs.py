"""Model / experiment configuration shared by methods.py, model.py, aot.py.

Sizes are scaled so every experiment in the paper's evaluation runs on a
single CPU core through the PJRT runtime (see DESIGN.md §4 for the
substitution table). The *structure* — which matrices are adapted, how
each PEFT method parameterizes them, the d/D ratios — follows the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelCfg:
    """One (backbone, method, head) combination = one pair of artifacts."""

    name: str = "base"
    vocab: int = 512
    seq: int = 32
    hidden: int = 64
    layers: int = 2
    heads: int = 4
    ffn: int = 128
    # PEFT
    method: str = "uni"       # see methods.REGISTRY
    rank: int = 4
    d: int = 256              # subspace dim (uni family / fastfood / vb...)
    scale: float = 2.0        # lora alpha/r scaling applied to DeltaW
    # head
    n_classes: int = 2        # 0 = LM head (frozen, part of base); 1 = regression
    batch: int = 32
    # method extras
    vb_b: int = 64            # VB-LoRA sub-vector length
    vb_k: int = 2             # VB-LoRA top-K
    vb_bank: int = 24         # VB-LoRA bank size h
    n_coef: int = 96          # FourierFT coefficients per module
    use_pallas: bool = True   # route uni/fastfood projections through L1 kernels

    @property
    def n_modules(self) -> int:
        """Adapted modules: q and v per layer (paper §4.1)."""
        return 2 * self.layers

    @property
    def module_len(self) -> int:
        """Per-module LoRA params: A [h, r] + B [r, h]."""
        return 2 * self.hidden * self.rank

    @property
    def d_full(self) -> int:
        """D = total LoRA parameter count across adapted modules."""
        return self.n_modules * self.module_len

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads


# Backbone families (see DESIGN.md §4: MiniLM stands in for RoBERTa etc.)
BASE = ModelCfg(name="base", hidden=64, layers=2, ffn=128, heads=4, seq=32)
LARGE = ModelCfg(name="large", hidden=96, layers=3, ffn=192, heads=4, seq=32)
LM = ModelCfg(name="lm", hidden=128, layers=4, ffn=256, heads=4, seq=64,
              vocab=512, n_classes=0, batch=16, d=1024)
E2E = ModelCfg(name="e2e", hidden=256, layers=8, ffn=1024, heads=8, seq=64,
               vocab=2048, n_classes=0, batch=8, d=4096)


def with_method(cfg: ModelCfg, method: str, **kw) -> ModelCfg:
    return replace(cfg, method=method, **kw)
