"""The unified framework (paper §3.2): every PEFT method is a projection
theta_D = P theta_d, realized here as (a) a layout for the trainable
vector theta_d, (b) a spec for frozen statics (the implicit P, generated
from a seed — by numpy here for tests, by rust/src/projection at
runtime), and (c) an `apply` that computes the adapted matmul
y = x @ W0 + scale * DeltaW-contribution for one module.

Methods (Table 1 of the paper):
  lora        P = I (d = D)                                 [identity]
  uni         each row one-hot, uniform column, 1/sqrt(n_j) [ours]
  local       same, but per-layer subspace slices           [ablation T7]
  nonuniform  same, but A->2/3 of slots, B->1/3             [ablation T7]
  fastfood    S.H.G.Pi.H.B structured projection            [ablation T6]
  vera        frozen shared P_A/P_B + trainable diag pair   [baseline]
  tied        trainable shared P_A/P_B + diag pair          [baseline]
  vb          vector bank + fixed top-K admixture           [baseline]
  lora_xs     frozen per-module bases + trainable r x r     [baseline]
  fourierft   frozen random Fourier bases + trainable coefs [baseline]
  none        no adapter (linear probing)                   [Table 5 LP]

Statics generation must stay bit-identical with rust/src/projection/*.rs
(both sides build on the shared SplitMix64 streams in unirng / rng.rs).
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from . import unirng as rng
from .configs import ModelCfg
from .kernels import fastfood as ff_kernel
from .kernels import ref as kref
from .kernels import unilora as uni_kernel

F32, I32 = "f32", "i32"


# --------------------------------------------------------------------------
# helpers


def _seg_offsets(segments):
    """[(name, shape, init)] -> dict name -> (offset, shape)."""
    out, off = {}, 0
    for name, shape, _init in segments:
        n = int(np.prod(shape))
        out[name] = (off, tuple(shape))
        off += n
    return out, off


def unflatten(theta, segments):
    """Split the flat trainable vector into named jnp views."""
    offs, total = _seg_offsets(segments)
    assert theta.shape[0] == total, (theta.shape, total)
    return {
        name: theta[o: o + int(np.prod(s))].reshape(s)
        for name, (o, s) in offs.items()
    }


def init_array(init: str, shape, seed: int) -> np.ndarray:
    """Materialize an init spec string (mirrored by rust adapters::init)."""
    n = int(np.prod(shape))
    if init == "zeros":
        return np.zeros(shape, np.float32)
    if init == "ones":
        return np.ones(shape, np.float32)
    if init.startswith("normal:"):
        s = float(init.split(":")[1])
        return (rng.normals(seed, n) * s).reshape(shape).astype(np.float32)
    if init.startswith("uniform:"):
        a = float(init.split(":")[1])
        return rng.uniform_range(seed, n, -a, a).reshape(shape)
    if init.startswith("const:"):
        return np.full(shape, float(init.split(":")[1]), np.float32)
    raise ValueError(f"unknown init {init!r}")


def init_theta(cfg: ModelCfg, seed: int) -> np.ndarray:
    """Build the initial trainable vector (used by tests; rust mirrors)."""
    parts = []
    for i, (name, shape, init) in enumerate(theta_segments(cfg)):
        parts.append(
            init_array(init, shape, rng.child_seed(seed, rng.STREAM_THETA_INIT + 1000 * i)).ravel()
        )
    if not parts:
        return np.zeros((1,), np.float32)
    return np.concatenate(parts)


def _mgs_columns(a: np.ndarray) -> np.ndarray:
    """Modified Gram-Schmidt column orthonormalization (float64 in,
    sequential per-element dot products to stay bit-comparable with the
    Rust mirror within f32 tolerance)."""
    a = a.copy()
    h, r = a.shape
    for j in range(r):
        v = a[:, j]
        for i in range(j):
            v -= float(np.dot(a[:, i], v)) * a[:, i]
        a[:, j] = v / float(np.sqrt(np.dot(v, v)))
    return a


def _patch_support(idx: np.ndarray, d: int, used: int, patch_seed: int) -> np.ndarray:
    """Give every empty column in [0, used) a row stolen from a column
    with occupancy >= 2. Deterministic; MUST match rust uni.rs
    (rejection-sample up to 10_000 draws, then fall back to a linear
    scan so a skewed occupancy distribution can never hang)."""
    idx = idx.copy()
    cnt = np.bincount(idx, minlength=d)
    stream_pos = 0
    for j in range(used):
        if cnt[j] > 0:
            continue
        patched = False
        for _ in range(10_000):
            row = rng.value(patch_seed, stream_pos) % len(idx)
            stream_pos += 1
            if cnt[idx[row]] >= 2:
                cnt[idx[row]] -= 1
                idx[row] = j
                cnt[j] = 1
                patched = True
                break
        if patched:
            continue
        row = next(k for k in range(len(idx)) if cnt[idx[k]] >= 2)
        cnt[idx[row]] -= 1
        idx[row] = j
        cnt[j] = 1
    return idx


def _uni_counts_to_nrm(idx: np.ndarray, d: int) -> np.ndarray:
    cnt = np.bincount(idx, minlength=d).astype(np.float64)
    return (1.0 / np.sqrt(np.maximum(cnt, 1.0)))[idx].astype(np.float32)


# --------------------------------------------------------------------------
# per-method specs


def theta_segments(cfg: ModelCfg):
    """Trainable-vector layout: list of (name, shape, init)."""
    h, r, L, nm, d = cfg.hidden, cfg.rank, cfg.layers, cfg.n_modules, cfg.d
    m = cfg.method
    if m == "lora":
        segs = []
        for i in range(nm):
            # B zero-init so DeltaW = 0 at start (standard LoRA init).
            segs.append((f"A{i}", (h, r), "normal:0.02"))
            segs.append((f"B{i}", (r, h), "zeros"))
        return segs
    if m in ("uni", "local", "nonuniform", "fastfood"):
        return [("theta", (d,), "uniform:0.02")]  # paper: U(-0.02, 0.02)
    if m == "vera":
        # VeRA init: lambda_d = 0.1, lambda_b = 0 -> DeltaW = 0 at start.
        return [("lamb_b", (nm, h), "zeros"), ("lamb_d", (nm, r), "const:0.1")]
    if m == "tied":
        return [
            ("pa_t", (h, r), "normal:0.02"),
            ("pb_t", (r, h), "normal:0.02"),
            ("lamb_b", (nm, h), "zeros"),
            ("lamb_d", (nm, r), "const:0.1"),
        ]
    if m == "vb":
        n_sub = cfg.d_full // cfg.vb_b
        return [
            ("bank", (cfg.vb_bank, cfg.vb_b), "uniform:0.02"),
            ("coef", (n_sub, cfg.vb_k), "const:0.5"),
        ]
    if m == "lora_xs":
        return [(f"R{i}", (r, r), "zeros") for i in range(nm)]
    if m == "fourierft":
        return [("coef", (nm, cfg.n_coef), "zeros")]
    if m == "none":
        return []
    raise ValueError(f"unknown method {cfg.method!r}")


def d_effective(cfg: ModelCfg) -> int:
    """Number of trainable adapter parameters (reported in every table)."""
    _, total = _seg_offsets(theta_segments(cfg))
    return max(total, 1)


def statics_spec(cfg: ModelCfg):
    """Frozen side inputs (the implicit P): list of (name, dtype, shape)."""
    h, r, nm, d, D = cfg.hidden, cfg.rank, cfg.n_modules, cfg.d, cfg.d_full
    m = cfg.method
    if m in ("uni", "local", "nonuniform"):
        return [("idx", I32, (D,)), ("nrm", F32, (D,))]
    if m == "fastfood":
        nb = math.ceil(cfg.module_len / d)
        return [
            ("sgn_b", F32, (nm, nb, d)),
            ("gauss", F32, (nm, nb, d)),
            ("perm", I32, (nm, nb, d)),
            ("sgn_s", F32, (nm, nb, d)),
        ]
    if m == "vera":
        return [("pa_t", F32, (h, r)), ("pb_t", F32, (r, h))]
    if m == "vb":
        n_sub = D // cfg.vb_b
        return [("top_idx", I32, (n_sub, cfg.vb_k))]
    if m == "lora_xs":
        return [("pa_t", F32, (nm, h, r)), ("pb_t", F32, (nm, r, h))]
    if m == "fourierft":
        return [("freq", I32, (nm, cfg.n_coef, 2))]
    return []  # lora, tied, none


def gen_statics(cfg: ModelCfg, seed: int) -> dict[str, np.ndarray]:
    """Generate the frozen statics from a seed. MUST stay bit-identical
    with rust/src/projection/statics.rs (same streams, same order)."""
    h, r, nm, d, D = cfg.hidden, cfg.rank, cfg.n_modules, cfg.d, cfg.d_full
    m = cfg.method
    out: dict[str, np.ndarray] = {}
    if m in ("uni", "local", "nonuniform"):
        # d > D admits no assignment with full column support; bail like
        # rust ModelCfg::validate instead of looping in _patch_support.
        if d > D:
            raise ValueError(
                f"cfg {cfg.name}: subspace dim d = {d} exceeds D = {D} — "
                f"no projection with full column support exists"
            )
        # Paper footnote 1: re-sample P if any column is empty (keeps the
        # n_j > 0 assumption of Theorem 1). Resampling loop MUST match
        # rust/src/projection/uni.rs: attempt k uses child_seed(s, k).
        s = rng.child_seed(seed, rng.STREAM_IDX)
        used = d if m != "local" else (d // cfg.layers) * cfg.layers
        for attempt in range(32):
            raw = rng.u64_stream(rng.child_seed(s, attempt), D)
            if m == "uni":
                idx = (raw % np.uint64(d)).astype(np.int64)
            elif m == "local":
                # per-layer subspace slices of size d/L (ablation, Table 7)
                dl = d // cfg.layers
                idx = np.empty(D, np.int64)
                per_layer = 2 * cfg.module_len
                for l in range(cfg.layers):
                    lo, hi = l * per_layer, (l + 1) * per_layer
                    idx[lo:hi] = l * dl + (raw[lo:hi] % np.uint64(dl)).astype(np.int64)
            else:  # nonuniform: A -> first 2d/3 slots, B -> last d/3
                da = 2 * d // 3
                db = d - da
                idx = np.empty(D, np.int64)
                ml, ar = cfg.module_len, cfg.hidden * cfg.rank
                for i in range(nm):
                    o = i * ml
                    idx[o: o + ar] = (raw[o: o + ar] % np.uint64(da)).astype(np.int64)
                    idx[o + ar: o + ml] = da + (
                        raw[o + ar: o + ml] % np.uint64(db)
                    ).astype(np.int64)
            if (np.bincount(idx, minlength=d)[:used] > 0).all():
                break
            if attempt == 31:
                # Low D/d ratio: resampling alone may never find full
                # support. Patch deterministically: give each empty
                # column a row stolen from a column with count >= 2.
                # MUST match rust/src/projection/uni.rs::patch_support.
                idx = _patch_support(idx, d, used, rng.child_seed(s, 999_983))
                break
        out["idx"] = idx.astype(np.int32)
        out["nrm"] = _uni_counts_to_nrm(idx, d)
    elif m == "fastfood":
        nb = math.ceil(cfg.module_len / d)
        sb = np.empty((nm, nb, d), np.float32)
        g = np.empty((nm, nb, d), np.float32)
        pm = np.empty((nm, nb, d), np.int32)
        ss = np.empty((nm, nb, d), np.float32)
        # Per-block seeds are nested child streams so no (module, block)
        # pair can collide: the old flat `STREAM_FASTFOOD + 16*i + j`
        # derivation repeated seeds across modules whenever nb > 16.
        # MUST match rust statics.rs::fastfood_block_seed.
        ff = rng.child_seed(seed, rng.STREAM_FASTFOOD)
        for i in range(nm):
            ms = rng.child_seed(ff, i)
            for j in range(nb):
                base = rng.child_seed(ms, j)
                sb[i, j] = rng.signs(rng.child_seed(base, 1), d)
                g[i, j] = rng.normals(rng.child_seed(base, 2), d)
                pm[i, j] = rng.permutation(rng.child_seed(base, 3), d).astype(np.int32)
                ss[i, j] = rng.signs(rng.child_seed(base, 4), d)
        out.update(sgn_b=sb, gauss=g, perm=pm, sgn_s=ss)
    elif m == "vera":
        s = 1.0 / math.sqrt(h)
        out["pa_t"] = (
            rng.normals(rng.child_seed(seed, rng.STREAM_VERA_PA), h * r) * s
        ).reshape(h, r).astype(np.float32)
        out["pb_t"] = (
            rng.normals(rng.child_seed(seed, rng.STREAM_VERA_PB), r * h) * s
        ).reshape(r, h).astype(np.float32)
    elif m == "vb":
        n_sub = D // cfg.vb_b
        s = rng.child_seed(seed, rng.STREAM_VB_TOPIDX)
        out["top_idx"] = rng.indices(s, n_sub * cfg.vb_k, cfg.vb_bank).reshape(
            n_sub, cfg.vb_k
        ).astype(np.int32)
    elif m == "lora_xs":
        # Orthonormal frozen bases (stand-in for the paper's SVD bases:
        # orthonormality is what makes LoRA-XS isometric in Table 1).
        # Modified Gram-Schmidt in float64, mirrored in rust statics.rs.
        pa = np.empty((nm, h, r), np.float32)
        pb = np.empty((nm, r, h), np.float32)
        for i in range(nm):
            base = rng.child_seed(seed, rng.STREAM_XS_BASES + i)
            ra = rng.normals(rng.child_seed(base, 1), h * r).reshape(h, r)
            rb = rng.normals(rng.child_seed(base, 2), r * h).reshape(r, h)
            pa[i] = _mgs_columns(ra.astype(np.float64)).astype(np.float32)
            pb[i] = _mgs_columns(rb.T.astype(np.float64)).T.astype(np.float32)
        out.update(pa_t=pa, pb_t=pb)
    elif m == "fourierft":
        f = np.empty((nm, cfg.n_coef, 2), np.int32)
        for i in range(nm):
            base = rng.child_seed(seed, rng.STREAM_FOURIER_FREQ + i)
            f[i, :, 0] = rng.indices(rng.child_seed(base, 1), cfg.n_coef, h)
            f[i, :, 1] = rng.indices(rng.child_seed(base, 2), cfg.n_coef, h)
        out["freq"] = f
    return out


# --------------------------------------------------------------------------
# apply: the adapted matmul for one module


def apply(cfg: ModelCfg, theta_map, statics, mod_i: int, x, w0):
    """y = x @ w0 + scale * DeltaW-path for adapted module mod_i.

    x: [..., h] (flattened to 2-D internally), w0: [h, h].
    """
    h, r, sc = cfg.hidden, cfg.rank, cfg.scale
    m = cfg.method
    lead = x.shape[:-1]
    x2 = x.reshape(-1, h)

    if m == "none":
        return (x2 @ w0).reshape(*lead, h)

    if m == "lora":
        a, b = theta_map[f"A{mod_i}"], theta_map[f"B{mod_i}"]
        y = x2 @ w0 + sc * ((x2 @ a) @ b)
    elif m in ("uni", "local", "nonuniform"):
        ml, ar = cfg.module_len, h * r
        o = mod_i * ml
        th = theta_map["theta"]
        ia, na = statics["idx"][o: o + ar], statics["nrm"][o: o + ar]
        ib, nb = statics["idx"][o + ar: o + ml], statics["nrm"][o + ar: o + ml]
        if cfg.use_pallas:
            y = uni_kernel.apply(x2, w0, th, ia, na, ib, nb, r, sc)
        else:
            y = kref.unilora_matmul_ref(x2, w0, th, ia, na, ib, nb, r, sc)
    elif m == "fastfood":
        th = theta_map["theta"]
        proj = ff_kernel.fastfood_project if cfg.use_pallas else kref.fastfood_project_ref
        nb = statics["sgn_b"].shape[1]
        flat = proj(
            th,
            statics["sgn_b"][mod_i],
            statics["gauss"][mod_i],
            statics["perm"][mod_i],
            statics["sgn_s"][mod_i],
            cfg.module_len,
        ) / math.sqrt(cfg.n_modules * nb)  # full-P isometry normalization
        a = flat[: h * r].reshape(h, r)
        b = flat[h * r:].reshape(r, h)
        y = x2 @ w0 + sc * ((x2 @ a) @ b)
    elif m in ("vera", "tied"):
        src = theta_map if m == "tied" else statics
        pa_t, pb_t = src["pa_t"], src["pb_t"]
        lb = theta_map["lamb_b"][mod_i]  # [h]
        ld = theta_map["lamb_d"][mod_i]  # [r]
        a = pa_t * ld[None, :]           # [h, r]
        b = pb_t * lb[None, :]           # [r, h]
        y = x2 @ w0 + sc * ((x2 @ a) @ b)
    elif m == "vb":
        bank, coef = theta_map["bank"], theta_map["coef"]
        ml = cfg.module_len
        n_sub_mod = ml // cfg.vb_b
        lo = mod_i * n_sub_mod
        ti = statics["top_idx"][lo: lo + n_sub_mod]      # [ns, K]
        cf = coef[lo: lo + n_sub_mod]                     # [ns, K]
        sub = jnp.einsum("sk,skb->sb", cf, bank[ti])      # [ns, b]
        flat = sub.reshape(ml)
        a = flat[: h * r].reshape(h, r)
        b = flat[h * r:].reshape(r, h)
        y = x2 @ w0 + sc * ((x2 @ a) @ b)
    elif m == "lora_xs":
        pa_t, pb_t = statics["pa_t"][mod_i], statics["pb_t"][mod_i]
        rr = theta_map[f"R{mod_i}"]
        y = x2 @ w0 + sc * (((x2 @ pa_t) @ rr.T) @ pb_t)
    elif m == "fourierft":
        c = theta_map["coef"][mod_i]                      # [n_coef]
        f = statics["freq"][mod_i]                        # [n_coef, 2]
        i = jnp.arange(h, dtype=jnp.float32)
        ang1 = 2.0 * jnp.pi * f[:, 0][:, None].astype(jnp.float32) * i[None, :] / h
        ang2 = 2.0 * jnp.pi * f[:, 1][:, None].astype(jnp.float32) * i[None, :] / h
        dw = (
            jnp.einsum("k,ki,kj->ij", c, jnp.cos(ang1), jnp.cos(ang2))
            - jnp.einsum("k,ki,kj->ij", c, jnp.sin(ang1), jnp.sin(ang2))
        ) / math.sqrt(cfg.n_coef)
        y = x2 @ (w0 + sc * dw)
    else:
        raise ValueError(f"unknown method {m!r}")
    return y.reshape(*lead, h)
