#!/usr/bin/env bash
# Record a per-commit perf snapshot: run the benches with JSON
# reporting on, then archive BENCH_*.json (plus the BENCH_*.prom
# Prometheus scrape the serving bench emits) under bench_history/
# keyed by the current commit — the ROADMAP "perf trajectory" loop.
# Regressions become visible by diffing consecutive snapshots.
#
# Usage: scripts/bench_snapshot.sh [bench ...]
#   (default benches: train_step projection serving)
set -euo pipefail

cd "$(dirname "$0")/.."

benches=("$@")
if [ ${#benches[@]} -eq 0 ]; then
  benches=(train_step projection serving)
fi

for b in "${benches[@]}"; do
  UNI_LORA_BENCH_JSON=1 cargo bench --bench "$b"
done

commit=$(git rev-parse --short=12 HEAD 2>/dev/null || echo "nogit")
stamp=$(date -u +%Y%m%dT%H%M%SZ)
dest="bench_history/${stamp}_${commit}"
mkdir -p "$dest"

shopt -s nullglob
archived=0
for f in BENCH_*.json BENCH_*.prom; do
  cp "$f" "$dest/$f"
  archived=$((archived + 1))
done

if [ "$archived" -eq 0 ]; then
  echo "bench_snapshot: no BENCH_*.json produced — nothing archived" >&2
  exit 1
fi
echo "bench_snapshot: archived $archived report(s) -> $dest"
